//! `SimulatedBackend` — the generalized PFL simulation loop, a faithful
//! implementation of paper Algorithm 1:
//!
//! ```text
//! repeat
//!   (C, θ') ← alg.get_next_central_contexts(θ, t)      // next_contexts
//!   for each context c_i ∈ C:
//!     sample cohort, distribute across workers          // scheduler
//!     workers: simulate_one_user → postprocess_one_user → accumulate
//!     Δ ← worker_reduce(partials)                        // all-reduce
//!     for p in reversed(P): Δ ← p.postprocess_server(Δ) // DP noise etc.
//!   θ ← alg.process_aggregated_statistics_all_contexts
//!   for b in callbacks: stop |= b.after_central_iteration(θ, t)
//! until stop
//! ```
//!
//! The backend simulates only the *computation* of FL: the only
//! synchronization is the per-round reduce over worker partials (§3.1).

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::aggregator::Aggregator;
use super::algorithm::FederatedAlgorithm;
use super::callbacks::Callback;
use super::context::{CentralContext, DispatchMode, DispatchSpec, Population};
use super::dispatch::{dispatcher_for, staleness_weight, steal_count, Dispatcher};
use super::metrics::Metrics;
use super::model::RustClip;
use super::postprocess::{Postprocessor, PpEnv};
use super::scheduler::{order, SchedulerKind};
use super::worker::{ModelFactory, WorkerPool, WorkerShared};
use crate::baselines::OverheadProfile;
use crate::comms::{PoolEvent, SocketPool};
use crate::data::{
    CohortSampler, FederatedDataset, GeneratorSource, MinibatchSampler, UserDataSource,
};
use crate::simsys::{current_rss_bytes, Counters, Timeline, TimelineRow, UserCost};
use crate::util::rng::Rng;

/// Everything a simulation run needs besides the algorithm + model.
pub struct RunParams {
    /// Worker replica count (the paper's g·p worker processes).
    pub num_workers: usize,
    pub scheduler: SchedulerKind,
    /// How cohorts reach workers (static barrier / pull queue / async
    /// buffered aggregation) — see [`crate::fl::dispatch`]. Stamped onto
    /// contexts that leave their spec at the default.
    pub dispatch: DispatchSpec,
    pub profile: OverheadProfile,
    pub seed: u64,
    /// Print a metrics line every k rounds (0 = silent).
    pub log_every: u64,
    /// Which clip kernel the per-user DP path uses. `Hlo` is the paper's
    /// on-device design (no host transfer on a real accelerator); on CPU
    /// PJRT the buffers are host-side anyway and the interpret-mode
    /// Pallas kernel is ~24x slower than the native path (§Perf), so the
    /// CPU default is `Rust`. Both are bit-compatible (tested).
    pub clip_backend: ClipBackend,
    /// Worker accumulation-arena tuning (sparse spill threshold) — see
    /// [`crate::tensor::ArenaConfig`].
    pub arena: crate::tensor::ArenaConfig,
    /// Reduce worker partials with the parallel binary tree fold
    /// ([`crate::fl::aggregator::tree_reduce`], `--fold-tree`) instead of
    /// the serial left fold. Off by default: the serial path stays
    /// byte-identical to previous releases; the tree is deterministic in
    /// its own right (fixed adjacent pairing) but associates f32 adds
    /// differently.
    pub fold_tree: bool,
    /// Worker threads for the counter-based DP noise engine
    /// (`--noise-threads`). 0 (default) keeps the legacy sequential
    /// noise stream byte-identical to previous releases; N ≥ 1 switches
    /// every mechanism to counter-keyed parallel kernels whose output is
    /// bit-identical for any N — and lets banded-MF regenerate past
    /// rounds' noise instead of retaining a `band × dim` ring.
    pub noise_threads: usize,
    /// Device-realism scenario (`--scenario`, DESIGN.md §8): speed
    /// tiers, diurnal availability windows and a mid-round dropout
    /// hazard, all pure functions of `(seed, uid, round)` via the
    /// counter RNG. Disabled by default — the off path is byte-identical
    /// to previous releases.
    pub scenario: crate::fl::device::ScenarioSpec,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClipBackend {
    Hlo,
    Rust,
}

impl Default for RunParams {
    fn default() -> Self {
        RunParams {
            num_workers: 1,
            scheduler: SchedulerKind::GreedyMedianBase,
            dispatch: DispatchSpec::default(),
            profile: OverheadProfile::default(),
            seed: 0,
            log_every: 0,
            clip_backend: ClipBackend::Rust,
            arena: crate::tensor::ArenaConfig::default(),
            fold_tree: false,
            noise_threads: 0,
            scenario: Default::default(),
        }
    }
}

/// The result of a full simulation run.
pub struct RunOutcome {
    /// Final central model parameters.
    pub central: Vec<f32>,
    /// Central iterations completed.
    pub rounds: u64,
    pub wall_secs: f64,
    /// Per-round metrics (train + namespaced val + sys).
    pub history: Vec<(u64, Metrics)>,
    /// Merged system counters across all workers and rounds.
    pub counters: Counters,
    /// Per-round timeline (Figs. 7–8 output format).
    pub timeline: Timeline,
    /// Per-round wall-clock nanos.
    pub round_nanos: Vec<u64>,
    /// Per-round measured straggler gap (Table 5 / Fig. 5).
    pub straggler_nanos: Vec<u64>,
    /// Per-user (datapoints, nanos) records sampled across the run
    /// (Fig. 4a; virtual-cluster replay input).
    pub user_costs: Vec<UserCost>,
    /// Per-worker busy nanos summed over rounds (GPU-hours analogue).
    pub worker_busy_nanos: Vec<u64>,
}

impl RunOutcome {
    /// Last value of a metric across the history.
    pub fn final_metric(&self, name: &str) -> Option<f64> {
        self.history.iter().rev().find_map(|(_, m)| m.get(name))
    }

    /// Full series of a metric: (round, value).
    pub fn series(&self, name: &str) -> Vec<(u64, f64)> {
        self.history
            .iter()
            .filter_map(|(t, m)| m.get(name).map(|v| (*t, v)))
            .collect()
    }
}

/// The simulation backend (paper App. B.1 "Backend"; the only concrete
/// backend, as in pfl-research's initial release).
pub struct SimulatedBackend {
    dataset: Arc<dyn FederatedDataset>,
    val_dataset: Arc<dyn FederatedDataset>,
    algorithm: Arc<dyn FederatedAlgorithm>,
    aggregator: Arc<dyn Aggregator>,
    postprocessors: Arc<Vec<Box<dyn Postprocessor>>>,
    sampler: Box<dyn CohortSampler>,
    /// The workers' user-data source (shared with the pool); the round
    /// loops feed it each round's dispatch order so a store-backed
    /// source can prefetch ahead of consumption.
    source: Arc<dyn UserDataSource>,
    /// Engine-level cohort distribution policy (`RunParams::dispatch`);
    /// contexts carrying a different mode get an ad-hoc dispatcher.
    dispatcher: Box<dyn Dispatcher>,
    pool: WorkerPool,
    params: RunParams,
}

pub struct BackendBuilder {
    pub dataset: Arc<dyn FederatedDataset>,
    pub val_dataset: Option<Arc<dyn FederatedDataset>>,
    pub algorithm: Arc<dyn FederatedAlgorithm>,
    pub aggregator: Option<Arc<dyn Aggregator>>,
    pub postprocessors: Vec<Box<dyn Postprocessor>>,
    pub sampler: Option<Box<dyn CohortSampler>>,
    pub factory: ModelFactory,
    pub params: RunParams,
    /// Where workers fetch user data. `None` (default) generates lazily
    /// from `dataset` — the pre-store behavior, byte-identical. Set an
    /// out-of-core [`crate::data::StoreSource`] for materialized data
    /// with caching + dispatcher-driven prefetch (`--data-store`).
    pub data_source: Option<Arc<dyn UserDataSource>>,
}

impl BackendBuilder {
    pub fn new(
        dataset: Arc<dyn FederatedDataset>,
        algorithm: Arc<dyn FederatedAlgorithm>,
        factory: ModelFactory,
    ) -> Self {
        BackendBuilder {
            dataset,
            val_dataset: None,
            algorithm,
            aggregator: None,
            postprocessors: Vec::new(),
            sampler: None,
            factory,
            params: RunParams::default(),
            data_source: None,
        }
    }

    pub fn data_source(mut self, source: Arc<dyn UserDataSource>) -> Self {
        self.data_source = Some(source);
        self
    }

    pub fn postprocessor(mut self, pp: Box<dyn Postprocessor>) -> Self {
        self.postprocessors.push(pp);
        self
    }

    pub fn params(mut self, params: RunParams) -> Self {
        self.params = params;
        self
    }

    pub fn val_dataset(mut self, ds: Arc<dyn FederatedDataset>) -> Self {
        self.val_dataset = Some(ds);
        self
    }

    pub fn sampler(mut self, s: Box<dyn CohortSampler>) -> Self {
        self.sampler = Some(s);
        self
    }

    pub fn build(self) -> Result<SimulatedBackend> {
        let postprocessors = Arc::new(self.postprocessors);
        // one aggregator instance, shared between the workers (arena
        // compatibility / accumulate) and the backend (worker_reduce)
        let aggregator = self
            .aggregator
            .unwrap_or_else(|| Arc::new(super::aggregator::SumAggregator) as Arc<dyn Aggregator>);
        // one source instance, shared between the workers (fetch) and
        // the backend (per-round prefetch hints)
        let source = self
            .data_source
            .unwrap_or_else(|| Arc::new(GeneratorSource::new(self.dataset.clone())));
        let shared = WorkerShared {
            source: source.clone(),
            algorithm: self.algorithm.clone(),
            postprocessors: postprocessors.clone(),
            aggregator: aggregator.clone(),
            factory: self.factory,
            profile: self.params.profile.clone(),
            seed: self.params.seed,
            use_hlo_clip: self.params.clip_backend == ClipBackend::Hlo,
            arena: self.params.arena,
            noise_threads: self.params.noise_threads,
            scenario: self.params.scenario,
        };
        let pool = WorkerPool::new(self.params.num_workers, shared)?;
        Ok(SimulatedBackend {
            val_dataset: self.val_dataset.unwrap_or_else(|| self.dataset.clone()),
            dataset: self.dataset,
            algorithm: self.algorithm,
            aggregator,
            postprocessors,
            sampler: self.sampler.unwrap_or_else(|| Box::new(MinibatchSampler { cohort_size: 0 })),
            source,
            dispatcher: dispatcher_for(self.params.dispatch, self.params.scheduler),
            pool,
            params: self.params,
        })
    }
}

impl SimulatedBackend {
    /// Run the full simulation from `central` (paper Alg. 1). Callbacks
    /// run on this thread after every central iteration and may stop
    /// training early. With `RunParams::dispatch` in `Async` mode the
    /// buffered-aggregation engine ([`Self::run_async`]) replaces the
    /// barrier loop.
    pub fn run(
        &mut self,
        mut central: Vec<f32>,
        callbacks: &mut [Box<dyn Callback>],
    ) -> Result<RunOutcome> {
        if self.params.dispatch.mode == DispatchMode::Async {
            return self.run_async(central, callbacks);
        }
        if self.params.dispatch.mode == DispatchMode::Socket {
            return Err(anyhow!(
                "socket dispatch needs worker connections: bind a comms::SocketServer, \
                 accept the workers into a SocketPool and call \
                 SimulatedBackend::run_distributed instead of run"
            ));
        }
        let start = Instant::now();
        let mut server_rng = Rng::seed_from_u64(self.params.seed ^ 0x5E12_4E4D);
        let mut outcome = self.fresh_outcome();

        let mut t: u64 = 0;
        'outer: loop {
            let mut contexts = self.algorithm.next_contexts(t);
            if contexts.is_empty() {
                break; // the algorithm signaled training should end
            }
            for c in &mut contexts {
                if c.dispatch == DispatchSpec::default() {
                    // the default spec is the "inherit the engine policy"
                    // sentinel (see `DispatchSpec`)
                    c.dispatch = self.params.dispatch;
                } else if c.dispatch.mode == DispatchMode::Async {
                    // buffered aggregation restructures the whole loop;
                    // it cannot be honored per-context under the
                    // synchronous engine — fail loudly instead of
                    // silently degrading to a barriered round
                    return Err(anyhow!(
                        "context at iteration {t} requests async dispatch, but the engine \
                         runs the synchronous loop; set RunParams::dispatch to the async \
                         spec instead"
                    ));
                }
            }
            let round_start = Instant::now();
            let busy_before: u64 = outcome.worker_busy_nanos.iter().sum();
            let mut round_metrics = Metrics::new();

            for ctx in &contexts {
                let (agg, metrics) = self
                    .run_context(ctx, &central, &mut server_rng, &mut outcome)
                    .with_context(|| format!("iteration {t} ({:?})", ctx.population))?;
                match ctx.population {
                    Population::Train => {
                        round_metrics.merge(&metrics);
                        if let Some(mut agg) = agg {
                            // densify once at the chokepoint: algorithms
                            // consume the aggregate through dense slices,
                            // and a sparse aggregate reaching one that
                            // forgot densify_all() would silently no-op
                            agg.densify_all();
                            self.algorithm
                                .process_aggregated(&mut central, ctx, agg, &mut round_metrics)?;
                        }
                    }
                    Population::Val => round_metrics.merge(&metrics.prefixed("val/")),
                }
            }

            let stop =
                self.close_round(&mut outcome, callbacks, &central, t, round_metrics, round_start, start, busy_before)?;
            t += 1;
            if stop {
                break 'outer;
            }
        }

        self.finish_run(outcome, central, callbacks, start)
    }

    /// The async buffered-aggregation engine (dispatch mode `Async`,
    /// FedBuff-style): users are streamed to workers one at a time, the
    /// server folds the first K arrivals of each round weighted by
    /// staleness ([`staleness_weight`]) and opens the next context
    /// without waiting for stragglers — there is no all-worker barrier,
    /// so the round count is independent of the slowest worker. Arrivals
    /// staler than `max_staleness` rounds are dropped (counted in
    /// `Counters::dropped_updates`). Federated-eval contexts are barrier
    /// phases: the engine drains in-flight users (dropping their
    /// updates) before evaluating.
    fn run_async(
        &mut self,
        mut central: Vec<f32>,
        callbacks: &mut [Box<dyn Callback>],
    ) -> Result<RunOutcome> {
        let start = Instant::now();
        let mut server_rng = Rng::seed_from_u64(self.params.seed ^ 0x5E12_4E4D);
        let mut outcome = self.fresh_outcome();
        let spec = self.params.dispatch;
        let workers = self.pool.num_workers;
        // one round loop, two arrival disciplines: physical order, or
        // dispatch order through the replay reorder buffer
        let mut driver = if spec.reorder_window > 0 {
            AsyncDriver::Replay(ReplayEngine::default())
        } else {
            AsyncDriver::Physical(AsyncEngine {
                inflight: vec![false; workers],
                idle: (0..workers).collect(),
            })
        };

        let mut t: u64 = 0;
        'outer: loop {
            let mut contexts = self.algorithm.next_contexts(t);
            if contexts.is_empty() {
                break;
            }
            for c in &mut contexts {
                // the async engine owns dispatch wholesale — per-context
                // overrides do not apply in this mode
                c.dispatch = spec;
            }
            let round_start = Instant::now();
            let busy_before: u64 = outcome.worker_busy_nanos.iter().sum();
            let mut round_metrics = Metrics::new();

            for ctx in &contexts {
                match ctx.population {
                    Population::Val => {
                        // eval is a barrier phase: wait out + drop the
                        // in-flight tail before evaluating
                        self.drain_async(&mut driver, &mut outcome)?;
                        let (_, metrics) =
                            self.run_context(ctx, &central, &mut server_rng, &mut outcome)?;
                        round_metrics.merge(&metrics.prefixed("val/"));
                    }
                    Population::Train => {
                        let (agg, metrics) = match &mut driver {
                            AsyncDriver::Physical(engine) => self.run_async_train_context(
                                ctx,
                                &central,
                                &mut server_rng,
                                &mut outcome,
                                engine,
                            )?,
                            AsyncDriver::Replay(engine) => self.run_replay_train_context(
                                ctx,
                                &central,
                                &mut server_rng,
                                &mut outcome,
                                engine,
                            )?,
                        };
                        round_metrics.merge(&metrics);
                        if let Some(mut agg) = agg {
                            agg.densify_all();
                            self.algorithm
                                .process_aggregated(&mut central, ctx, agg, &mut round_metrics)?;
                        }
                    }
                }
            }

            let stop =
                self.close_round(&mut outcome, callbacks, &central, t, round_metrics, round_start, start, busy_before)?;
            t += 1;
            if stop {
                break 'outer;
            }
        }

        // in-flight users trained past the horizon: wait out + drop
        self.drain_async(&mut driver, &mut outcome)?;
        self.finish_run(outcome, central, callbacks, start)
    }

    /// Barrier shared by both async arrival disciplines.
    fn drain_async(&self, driver: &mut AsyncDriver, outcome: &mut RunOutcome) -> Result<()> {
        match driver {
            AsyncDriver::Physical(engine) => self.drain_inflight(engine, outcome),
            AsyncDriver::Replay(engine) => self.drain_replay(engine, outcome),
        }
    }

    /// One deterministic-replay train context (`reorder_window > 0`).
    /// Same buffered-aggregation semantics as
    /// [`Self::run_async_train_context`], but every quantity that is
    /// physical-timing-dependent there is a deterministic function of
    /// the dispatch sequence here, so runs are **bit-identical across
    /// worker counts**:
    ///
    /// * at most `reorder_window` commands are logically outstanding;
    ///   each carries a monotone sequence number and is assigned to
    ///   worker `seq % W` (worker channels execute FIFO, so commands
    ///   beyond the worker count simply queue);
    /// * the server folds results strictly in dispatch (round, uid)
    ///   order — an arrival whose sequence number is ahead of the fold
    ///   cursor parks in a reorder buffer (bounded by the window) until
    ///   its turn, topping the window back up after every fold;
    /// * staleness is `current round − dispatch round` of the *expected*
    ///   command, which no longer depends on which worker ran it or how
    ///   fast.
    ///
    /// The window caps exploitable parallelism (pick ≥ the worker
    /// count); physical arrival order still varies run to run, but the
    /// fold consumes it through the reorder buffer, so the reduced
    /// statistics, drops, staleness discounts and central updates do
    /// not. Cohort members never dispatched when the buffer fills are
    /// abandoned, exactly like the physical-order engine.
    fn run_replay_train_context(
        &self,
        ctx: &CentralContext,
        central: &[f32],
        server_rng: &mut Rng,
        outcome: &mut RunOutcome,
        engine: &mut ReplayEngine,
    ) -> Result<(Option<super::stats::Statistics>, Metrics)> {
        let (mut pending, cohort_len, k, central_arc, unavailable) =
            self.async_cohort(ctx, central);
        let window = ctx.dispatch.reorder_window.max(1);
        let cache0 = StoreSnap::take(&outcome.counters);
        let dropped0 = outcome.counters.dropout_users;

        let mut metrics = Metrics::new();
        let mut acc: Option<super::stats::Statistics> = None;
        let mut folded = 0usize;
        let mut arrivals = 0u64;
        let mut stale_folds = 0u64;
        let mut round_stat_elements = 0u64;
        let mut round_stat_bytes = 0u64;

        self.replay_top_up(engine, &mut pending, ctx, &central_arc, window)?;
        while folded < k {
            let Some(head) = engine.outstanding.front().copied() else {
                break; // cohort exhausted before the buffer filled
            };
            let r = self.replay_recv(engine, head.seq)?;
            engine.outstanding.pop_front();
            arrivals += 1;
            round_stat_elements += r.counters.stat_elements;
            round_stat_bytes += r.counters.stat_bytes;
            Self::absorb_result_bookkeeping(outcome, &r);
            // deterministic staleness: dispatch round of the expected
            // command vs the current context (r.round echoes head.round)
            let staleness = ctx.iteration.saturating_sub(head.round);
            if self.fold_async_arrival(
                outcome,
                &mut metrics,
                &mut acc,
                r,
                staleness,
                ctx.dispatch.max_staleness,
                &mut stale_folds,
            ) {
                folded += 1;
            }
            self.replay_top_up(engine, &mut pending, ctx, &central_arc, window)?;
        }

        metrics.add_central(
            "sys/reorder-outstanding",
            engine.outstanding.len() as f64,
            1.0,
        );
        self.finish_async_train_context(
            ctx,
            server_rng,
            outcome,
            acc,
            metrics,
            cohort_len,
            folded,
            stale_folds,
            round_stat_elements,
            round_stat_bytes,
            cache0,
            unavailable,
            arrivals,
            dropped0,
        )
    }

    /// Keep `window` commands outstanding, drawing from this round's
    /// pending queue. Worker choice is `seq % W`: deterministic, and
    /// irrelevant to the results (commands queue FIFO per worker).
    fn replay_top_up(
        &self,
        engine: &mut ReplayEngine,
        pending: &mut VecDeque<usize>,
        ctx: &CentralContext,
        central: &Arc<Vec<f32>>,
        window: usize,
    ) -> Result<()> {
        while engine.outstanding.len() < window {
            let Some(uid) = pending.pop_front() else { break };
            let seq = engine.next_seq;
            engine.next_seq += 1;
            let w = (seq % self.pool.num_workers as u64) as usize;
            self.pool.send_user(w, ctx, central.clone(), uid, seq)?;
            engine.outstanding.push_back(Outstanding { seq, round: ctx.iteration });
        }
        Ok(())
    }

    /// Receive the result for `seq`, parking earlier-than-expected
    /// arrivals in the reorder buffer (bounded by the outstanding
    /// window).
    fn replay_recv(&self, engine: &mut ReplayEngine, seq: u64) -> Result<super::worker::RoundResult> {
        if let Some(r) = engine.parked.remove(&seq) {
            return Ok(r);
        }
        loop {
            let r = self.pool.recv_result()?;
            if let Some(err) = &r.error {
                return Err(anyhow!("worker {} failed: {err}", r.worker));
            }
            if r.seq == seq {
                return Ok(r);
            }
            engine.parked.insert(r.seq, r);
        }
    }

    /// Replay-mode barrier: wait out every outstanding command in
    /// dispatch order, dropping (and counting) their updates.
    fn drain_replay(&self, engine: &mut ReplayEngine, outcome: &mut RunOutcome) -> Result<()> {
        while let Some(head) = engine.outstanding.pop_front() {
            let r = self.replay_recv(engine, head.seq)?;
            Self::absorb_result_bookkeeping(outcome, &r);
            if r.partial.is_some() {
                outcome.counters.dropped_updates += 1;
            }
        }
        debug_assert!(engine.parked.is_empty(), "reorder buffer outlived its window");
        Ok(())
    }

    /// The multi-process distributed engine (`--dispatch socket`): the
    /// deterministic-replay round loop of [`Self::run_replay_train_context`],
    /// but with commands crossing a process boundary through a
    /// [`SocketPool`] instead of the in-process channels (DESIGN.md §7).
    ///
    /// Determinism carries over unchanged: commands are seq-stamped,
    /// at most `reorder_window` stay outstanding, and results fold
    /// strictly in dispatch order through the same reorder buffer — so
    /// a distributed run's central model is **bit-identical to the
    /// threaded async-replay run at the same seed**, for any worker
    /// process count (which worker runs a user never enters the
    /// numbers: per-user RNG is keyed by (run seed, context seed, uid)).
    ///
    /// Fault model: a worker that dies mid-round (EOF, I/O error, 3×
    /// heartbeat silence) surfaces as [`PoolEvent::Dead`]; its in-flight
    /// commands are re-sent *with their original sequence numbers* to
    /// the live workers, so the fold order — and therefore the result —
    /// is unchanged. Duplicate results (the original arrived after the
    /// death verdict) are dropped by seq. The run only fails when every
    /// connection is dead.
    ///
    /// Federated eval runs on the server's local replica pool after
    /// draining the distributed tail: eval folds no statistics, so this
    /// is bit-identical by construction and keeps worker processes
    /// training-only.
    pub fn run_distributed(
        &mut self,
        mut central: Vec<f32>,
        callbacks: &mut [Box<dyn Callback>],
        mut pool: SocketPool,
    ) -> Result<RunOutcome> {
        let start = Instant::now();
        let mut server_rng = Rng::seed_from_u64(self.params.seed ^ 0x5E12_4E4D);
        let mut outcome = self.fresh_outcome();
        // result bookkeeping indexes by worker slot; the socket slots may
        // outnumber the local (eval-only) pool
        if pool.num_workers() > outcome.worker_busy_nanos.len() {
            outcome.worker_busy_nanos.resize(pool.num_workers(), 0);
        }
        let mut spec = self.params.dispatch;
        spec.mode = DispatchMode::Socket;
        // a zero window would deadlock the fold loop (nothing outstanding)
        spec.reorder_window = spec.reorder_window.max(1);
        let mut engine = SocketEngine::default();

        let mut t: u64 = 0;
        'outer: loop {
            let mut contexts = self.algorithm.next_contexts(t);
            if contexts.is_empty() {
                break;
            }
            for c in &mut contexts {
                // the distributed engine owns dispatch wholesale, exactly
                // like the async engine
                c.dispatch = spec;
            }
            let round_start = Instant::now();
            let busy_before: u64 = outcome.worker_busy_nanos.iter().sum();
            let mut round_metrics = Metrics::new();

            for ctx in &contexts {
                match ctx.population {
                    Population::Val => {
                        self.socket_drain(&pool, &mut engine, &mut outcome)?;
                        let (_, metrics) =
                            self.run_context(ctx, &central, &mut server_rng, &mut outcome)?;
                        round_metrics.merge(&metrics.prefixed("val/"));
                    }
                    Population::Train => {
                        let (agg, metrics) = self.socket_train_context(
                            &pool,
                            ctx,
                            &central,
                            &mut server_rng,
                            &mut outcome,
                            &mut engine,
                        )?;
                        round_metrics.merge(&metrics);
                        if let Some(mut agg) = agg {
                            agg.densify_all();
                            self.algorithm
                                .process_aggregated(&mut central, ctx, agg, &mut round_metrics)?;
                        }
                    }
                }
            }

            let stop =
                self.close_round(&mut outcome, callbacks, &central, t, round_metrics, round_start, start, busy_before)?;
            t += 1;
            if stop {
                break 'outer;
            }
        }

        // commands trained past the horizon: wait out + drop, then an
        // orderly STOP to every live worker process
        self.socket_drain(&pool, &mut engine, &mut outcome)?;
        pool.shutdown();
        self.finish_run(outcome, central, callbacks, start)
    }

    /// One distributed train context — the socket twin of
    /// [`Self::run_replay_train_context`], plus the transport's own round
    /// metrics (`sys/requeued-users`, `sys/worker-reconnects`,
    /// `sys/wire-bytes-in`/`-out`).
    fn socket_train_context(
        &self,
        pool: &SocketPool,
        ctx: &CentralContext,
        central: &[f32],
        server_rng: &mut Rng,
        outcome: &mut RunOutcome,
        engine: &mut SocketEngine,
    ) -> Result<(Option<super::stats::Statistics>, Metrics)> {
        let (mut pending, cohort_len, k, central_arc, unavailable) =
            self.async_cohort(ctx, central);
        let window = ctx.dispatch.reorder_window.max(1);
        let cache0 = StoreSnap::take(&outcome.counters);
        let dropped0 = outcome.counters.dropout_users;
        let (in0, out0) = pool.wire_bytes();
        let requeued0 = engine.requeued_users;
        let reconnects0 = engine.reconnects;

        let mut metrics = Metrics::new();
        let mut acc: Option<super::stats::Statistics> = None;
        let mut folded = 0usize;
        let mut arrivals = 0u64;
        let mut stale_folds = 0u64;
        let mut round_stat_elements = 0u64;
        let mut round_stat_bytes = 0u64;

        self.socket_top_up(pool, engine, &mut pending, ctx, &central_arc, window)?;
        while folded < k {
            // the head stays in `outstanding` until its result is in
            // hand, so a worker death while we wait still requeues it
            let Some((head_seq, head_round)) =
                engine.outstanding.front().map(|o| (o.seq, o.round))
            else {
                break; // cohort exhausted before the buffer filled
            };
            let r = self.socket_recv(pool, engine, head_seq)?;
            engine.outstanding.pop_front();
            arrivals += 1;
            round_stat_elements += r.counters.stat_elements;
            round_stat_bytes += r.counters.stat_bytes;
            Self::absorb_result_bookkeeping(outcome, &r);
            let staleness = ctx.iteration.saturating_sub(head_round);
            if self.fold_async_arrival(
                outcome,
                &mut metrics,
                &mut acc,
                r,
                staleness,
                ctx.dispatch.max_staleness,
                &mut stale_folds,
            ) {
                folded += 1;
            }
            self.socket_top_up(pool, engine, &mut pending, ctx, &central_arc, window)?;
        }

        metrics.add_central(
            "sys/reorder-outstanding",
            engine.outstanding.len() as f64,
            1.0,
        );
        let (in1, out1) = pool.wire_bytes();
        let requeued = engine.requeued_users - requeued0;
        let reconnects = engine.reconnects - reconnects0;
        metrics.add_central("sys/requeued-users", requeued as f64, 1.0);
        metrics.add_central("sys/worker-reconnects", reconnects as f64, 1.0);
        metrics.add_central("sys/wire-bytes-in", (in1 - in0) as f64, 1.0);
        metrics.add_central("sys/wire-bytes-out", (out1 - out0) as f64, 1.0);
        outcome.counters.requeued_users += requeued;
        outcome.counters.worker_reconnects += reconnects;
        // worker results never carry these (they are transport-side), so
        // the running totals are plain assignments of the pool's gauges
        outcome.counters.wire_bytes_in = in1;
        outcome.counters.wire_bytes_out = out1;

        self.finish_async_train_context(
            ctx,
            server_rng,
            outcome,
            acc,
            metrics,
            cohort_len,
            folded,
            stale_folds,
            round_stat_elements,
            round_stat_bytes,
            cache0,
            unavailable,
            arrivals,
            dropped0,
        )
    }

    /// Keep `window` commands outstanding on the wire. Worker choice is
    /// the first *live* slot scanning from `seq % W` — deterministic
    /// when everyone is alive, and irrelevant to the results either way
    /// (the fold consumes seqs in dispatch order and per-user RNG never
    /// sees the worker id).
    fn socket_top_up(
        &self,
        pool: &SocketPool,
        engine: &mut SocketEngine,
        pending: &mut VecDeque<usize>,
        ctx: &CentralContext,
        central: &Arc<Vec<f32>>,
        window: usize,
    ) -> Result<()> {
        while engine.outstanding.len() < window {
            let Some(uid) = pending.pop_front() else { break };
            let seq = engine.next_seq;
            engine.next_seq += 1;
            let w = socket_worker_for(pool, seq)?;
            pool.send_round(w, ctx, central, &[uid], seq)?;
            engine.outstanding.push_back(SocketOutstanding {
                seq,
                round: ctx.iteration,
                uid,
                worker: w,
                ctx: ctx.clone(),
                central: central.clone(),
            });
        }
        Ok(())
    }

    /// Receive the result for `seq`, parking earlier-than-expected
    /// arrivals and servicing transport events: a death requeues the
    /// dead worker's in-flight commands (same seqs, live workers), a
    /// join marks a replacement available.
    fn socket_recv(
        &self,
        pool: &SocketPool,
        engine: &mut SocketEngine,
        seq: u64,
    ) -> Result<super::worker::RoundResult> {
        if let Some(r) = engine.parked.remove(&seq) {
            return Ok(r);
        }
        loop {
            match pool.recv_event()? {
                PoolEvent::Result(r) => {
                    let r = *r;
                    if let Some(err) = &r.error {
                        return Err(anyhow!("worker {} failed: {err}", r.worker));
                    }
                    if r.seq == seq {
                        return Ok(r);
                    }
                    // a command requeued after a death verdict can yield
                    // two results (the original was already in flight);
                    // accept only seqs still outstanding, first wins
                    if engine.outstanding.iter().any(|o| o.seq == r.seq) {
                        engine.parked.entry(r.seq).or_insert(r);
                    }
                }
                PoolEvent::Dead { worker, reason } => {
                    self.socket_requeue(pool, engine, worker, &reason)?;
                }
                PoolEvent::Joined { worker: _ } => {
                    engine.reconnects += 1;
                }
            }
        }
    }

    /// Re-send every command in flight on a dead worker to live workers,
    /// with the **original sequence numbers** — the fold order (and so
    /// the run's result) is unchanged by the failure. Commands whose
    /// result already arrived (parked) are skipped.
    fn socket_requeue(
        &self,
        pool: &SocketPool,
        engine: &mut SocketEngine,
        worker: usize,
        reason: &str,
    ) -> Result<()> {
        let mut moved = 0u64;
        for i in 0..engine.outstanding.len() {
            if engine.outstanding[i].worker != worker {
                continue;
            }
            let seq = engine.outstanding[i].seq;
            if engine.parked.contains_key(&seq) {
                continue; // its result beat the death verdict
            }
            let w = socket_worker_for(pool, seq)
                .with_context(|| format!("requeuing after worker {worker} died: {reason}"))?;
            {
                let o = &engine.outstanding[i];
                pool.send_round(w, &o.ctx, &o.central, &[o.uid], o.seq)?;
            }
            engine.outstanding[i].worker = w;
            moved += 1;
        }
        engine.requeued_users += moved;
        Ok(())
    }

    /// Distributed barrier: wait out every outstanding command in
    /// dispatch order, dropping (and counting) their updates.
    fn socket_drain(
        &self,
        pool: &SocketPool,
        engine: &mut SocketEngine,
        outcome: &mut RunOutcome,
    ) -> Result<()> {
        while let Some(head_seq) = engine.outstanding.front().map(|o| o.seq) {
            let r = self.socket_recv(pool, engine, head_seq)?;
            engine.outstanding.pop_front();
            Self::absorb_result_bookkeeping(outcome, &r);
            if r.partial.is_some() {
                outcome.counters.dropped_updates += 1;
            }
        }
        debug_assert!(engine.parked.is_empty(), "reorder buffer outlived its window");
        Ok(())
    }

    /// Per-round tail bookkeeping shared by both engines: round clock,
    /// baseline-emulation taxes, callbacks, logging, timeline row and
    /// history. Returns whether a callback requested an early stop.
    #[allow(clippy::too_many_arguments)]
    fn close_round(
        &self,
        outcome: &mut RunOutcome,
        callbacks: &mut [Box<dyn Callback>],
        central: &[f32],
        t: u64,
        mut round_metrics: Metrics,
        round_start: Instant,
        run_start: Instant,
        busy_before: u64,
    ) -> Result<bool> {
        let round_nanos = round_start.elapsed().as_nanos() as u64;
        outcome.round_nanos.push(round_nanos);
        round_metrics.add_central("sys/round-secs", round_nanos as f64 / 1e9, 1.0);

        self.apply_round_profile_taxes(central);

        let mut stop = false;
        for cb in callbacks.iter_mut() {
            stop |= cb.after_central_iteration(central, t, &mut round_metrics)?;
        }
        if self.params.log_every > 0 && t % self.params.log_every == 0 {
            println!("[round {t}] {round_metrics}");
        }
        let busy_round: u64 = outcome.worker_busy_nanos.iter().sum::<u64>() - busy_before;
        outcome.timeline.push(TimelineRow {
            round: t,
            wall_secs: run_start.elapsed().as_secs_f64(),
            rss_bytes: current_rss_bytes(),
            busy_frac: busy_frac(busy_round, round_nanos, self.pool.num_workers),
            loop_alloc_bytes: outcome.counters.loop_alloc_bytes,
            copy_bytes: outcome.counters.copy_bytes,
        });
        outcome.history.push((t, round_metrics));
        outcome.rounds = t + 1;
        Ok(stop)
    }

    /// Shared run epilogue: end-of-training callbacks + final outcome.
    fn finish_run(
        &self,
        mut outcome: RunOutcome,
        central: Vec<f32>,
        callbacks: &mut [Box<dyn Callback>],
        start: Instant,
    ) -> Result<RunOutcome> {
        for cb in callbacks.iter_mut() {
            cb.on_train_end(&central)?;
        }
        outcome.wall_secs = start.elapsed().as_secs_f64();
        outcome.central = central;
        Ok(outcome)
    }

    /// One async train context: stream this cohort's users to idle
    /// workers (heaviest first, per the scheduler's ordering policy) and
    /// fold arrivals — from this round or stale ones still streaming in —
    /// until the K-arrival buffer fills. Cohort members never dispatched
    /// when the buffer fills are abandoned (the server moves on).
    fn run_async_train_context(
        &self,
        ctx: &CentralContext,
        central: &[f32],
        server_rng: &mut Rng,
        outcome: &mut RunOutcome,
        engine: &mut AsyncEngine,
    ) -> Result<(Option<super::stats::Statistics>, Metrics)> {
        let (mut pending, cohort_len, k, central_arc, unavailable) =
            self.async_cohort(ctx, central);
        let cache0 = StoreSnap::take(&outcome.counters);
        let dropped0 = outcome.counters.dropout_users;

        let mut metrics = Metrics::new();
        let mut acc: Option<super::stats::Statistics> = None;
        let mut folded = 0usize;
        let mut arrivals = 0u64;
        let mut stale_folds = 0u64;
        let mut round_stat_elements = 0u64;
        let mut round_stat_bytes = 0u64;

        // prime every idle worker with one user of this round
        while let Some(&w) = engine.idle.last() {
            let Some(uid) = pending.pop_front() else { break };
            engine.idle.pop();
            self.pool.send_user(w, ctx, central_arc.clone(), uid, 0)?;
            engine.inflight[w] = true;
        }

        while folded < k {
            if !engine.inflight.iter().any(|&b| b) {
                break; // cohort exhausted before the buffer filled
            }
            let r = self.pool.recv_result()?;
            let w = r.worker;
            engine.inflight[w] = false;
            if let Some(err) = &r.error {
                return Err(anyhow!("worker {w} failed: {err}"));
            }
            arrivals += 1;
            round_stat_elements += r.counters.stat_elements;
            round_stat_bytes += r.counters.stat_bytes;
            Self::absorb_result_bookkeeping(outcome, &r);
            let staleness = ctx.iteration.saturating_sub(r.round);
            if self.fold_async_arrival(
                outcome,
                &mut metrics,
                &mut acc,
                r,
                staleness,
                ctx.dispatch.max_staleness,
                &mut stale_folds,
            ) {
                folded += 1;
            }
            // keep the worker busy with this round's remaining users
            if let Some(uid) = pending.pop_front() {
                self.pool.send_user(w, ctx, central_arc.clone(), uid, 0)?;
                engine.inflight[w] = true;
            } else {
                engine.idle.push(w);
            }
        }

        self.finish_async_train_context(
            ctx,
            server_rng,
            outcome,
            acc,
            metrics,
            cohort_len,
            folded,
            stale_folds,
            round_stat_elements,
            round_stat_bytes,
            cache0,
            unavailable,
            arrivals,
            dropped0,
        )
    }

    /// Shared cohort prologue of both async train engines: sample the
    /// cohort (availability-filtered on scenario runs), order it by
    /// scheduling weight (heaviest first, per the scheduler's ordering
    /// policy; speed tiers stretch the weights), size the K-arrival
    /// buffer and snapshot the central model for dispatch. Returns
    /// (pending queue, cohort size, K, central snapshot,
    /// unavailable-skipped count).
    fn async_cohort(
        &self,
        ctx: &CentralContext,
        central: &[f32],
    ) -> (VecDeque<usize>, usize, usize, Arc<Vec<f32>>, u64) {
        let (cohort, unavailable) = self.sample_cohort(ctx);
        let weights: Vec<f64> =
            cohort.iter().map(|&u| self.scheduling_weight(&self.dataset, u)).collect();
        let pending: VecDeque<usize> =
            order(self.params.scheduler, &weights).into_iter().map(|i| cohort[i]).collect();
        // async streaming consumes `pending` front to back: that is the
        // prefetcher's upcoming-uid order for this round
        if self.source.wants_hints() {
            let upcoming: Vec<usize> = pending.iter().copied().collect();
            self.source.hint_round(&upcoming);
        }
        let k = ctx.dispatch.buffer_k(cohort.len());
        (pending, cohort.len(), k, Arc::new(central.to_vec()), unavailable)
    }

    /// Shared round-metric epilogue of both async train engines — one
    /// place owns the sys/* schema so the two arrival disciplines can
    /// never drift apart. Wire volume counts everything that arrived
    /// this round, folded or dropped (a dropped update was still
    /// shipped), matching the synchronous engine's metric schema; the
    /// straggler series stays aligned at 0 because no barrier is paid.
    /// Ends with the server postprocessors (paper Alg. 1 l.18).
    #[allow(clippy::too_many_arguments)]
    fn finish_async_train_context(
        &self,
        ctx: &CentralContext,
        server_rng: &mut Rng,
        outcome: &mut RunOutcome,
        mut acc: Option<super::stats::Statistics>,
        mut metrics: Metrics,
        cohort_len: usize,
        folded: usize,
        stale_folds: u64,
        round_stat_elements: u64,
        round_stat_bytes: u64,
        cache0: StoreSnap,
        unavailable: u64,
        arrivals: u64,
        dropped0: u64,
    ) -> Result<(Option<super::stats::Statistics>, Metrics)> {
        metrics.add_central("sys/cohort", cohort_len as f64, 1.0);
        metrics.add_central("sys/async-folded", folded as f64, 1.0);
        metrics.add_central("sys/stale-updates", stale_folds as f64, 1.0);
        metrics.add_central("sys/user-update-elems", round_stat_elements as f64, 1.0);
        metrics.add_central("sys/user-update-bytes", round_stat_bytes as f64, 1.0);
        if self.params.scenario.enabled() {
            // device-realism accounting (DESIGN.md §8): every result
            // consumed this round either folded, hazard-dropped, was
            // staleness-dropped or carried no statistics — dropout-frac
            // is the hazard share of consumed arrivals, completion-rate
            // the folded share of the intended cohort. Emitted only on
            // scenario runs so the disabled metric schema stays
            // byte-identical to previous releases.
            let dropped = outcome.counters.dropout_users - dropped0;
            outcome.counters.unavailable_skipped += unavailable;
            metrics.add_central("sys/unavailable-skipped", unavailable as f64, 1.0);
            metrics.add_central("sys/dropout-frac", dropped as f64 / arrivals.max(1) as f64, 1.0);
            metrics.add_central(
                "sys/completion-rate",
                folded as f64 / cohort_len.max(1) as f64,
                1.0,
            );
        }
        store_metrics(&mut metrics, cache0, &outcome.counters);
        if let Some(a) = acc.as_ref() {
            metrics.add_central("sys/agg-elements", a.element_count() as f64, 1.0);
        }
        outcome.straggler_nanos.push(0);
        metrics.add_central("sys/straggler-secs", 0.0, 1.0);
        self.postprocess_server(acc.as_mut(), ctx, server_rng, &mut metrics, &mut outcome.counters)?;
        Ok((acc, metrics))
    }

    /// The fold step shared by both async engines (physical-order and
    /// deterministic replay): drop a too-stale arrival — the update
    /// never touches the model, so its train metrics stay out of the
    /// round's history too — otherwise discount it into the accumulator
    /// by [`staleness_weight`]. An arrival that trained but produced no
    /// statistics (e.g. an empty user) only contributes metrics.
    /// Returns true when the arrival was folded (counts toward the
    /// round's K-arrival buffer).
    #[allow(clippy::too_many_arguments)]
    fn fold_async_arrival(
        &self,
        outcome: &mut RunOutcome,
        metrics: &mut Metrics,
        acc: &mut Option<super::stats::Statistics>,
        r: super::worker::RoundResult,
        staleness: u64,
        max_staleness: u64,
        stale_folds: &mut u64,
    ) -> bool {
        match r.partial {
            Some(_) if staleness > max_staleness => {
                outcome.counters.dropped_updates += 1;
                false
            }
            Some(p) => {
                metrics.merge(&r.metrics);
                if staleness > 0 {
                    outcome.counters.stale_updates += 1;
                    *stale_folds += 1;
                }
                self.aggregator.accumulate_scaled(acc, p, staleness_weight(staleness));
                true
            }
            None => {
                metrics.merge(&r.metrics);
                false
            }
        }
    }

    /// Barrier for the async engine: wait out every in-flight user,
    /// dropping (and counting) their updates.
    fn drain_inflight(&self, engine: &mut AsyncEngine, outcome: &mut RunOutcome) -> Result<()> {
        while engine.inflight.iter().any(|&b| b) {
            let r = self.pool.recv_result()?;
            if let Some(err) = &r.error {
                return Err(anyhow!("worker {} failed: {err}", r.worker));
            }
            engine.inflight[r.worker] = false;
            engine.idle.push(r.worker);
            Self::absorb_result_bookkeeping(outcome, &r);
            if r.partial.is_some() {
                outcome.counters.dropped_updates += 1;
            }
        }
        Ok(())
    }

    /// Per-round overhead taxes of the baseline-engine emulations,
    /// applied by every dispatch mode's round loop.
    fn apply_round_profile_taxes(&self, central: &[f32]) {
        // full-participation bookkeeping tax (FedScale-like engines):
        // O(population) work per round.
        if self.params.profile.full_participation_bookkeeping {
            let mut acc = 0u64;
            for uid in 0..self.dataset.num_users() {
                acc = acc.wrapping_add(self.dataset.user_len(uid) as u64);
            }
            std::hint::black_box(acc);
        }
        if self.params.profile.checkpoint_every_round {
            // hard-coded per-round checkpointing (FedScale): serialize
            // the model to a scratch file.
            let path = std::env::temp_dir().join("pfl_baseline_ckpt.bin");
            let mut buf = Vec::with_capacity(central.len() * 4);
            for x in central {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            let _ = std::fs::write(path, &buf);
        }
    }

    fn fresh_outcome(&self) -> RunOutcome {
        RunOutcome {
            central: Vec::new(),
            rounds: 0,
            wall_secs: 0.0,
            history: Vec::new(),
            counters: Counters::default(),
            timeline: Timeline::default(),
            round_nanos: Vec::new(),
            straggler_nanos: Vec::new(),
            user_costs: Vec::new(),
            worker_busy_nanos: vec![0; self.pool.num_workers],
        }
    }

    /// Sample one context's cohort (with the postprocessors'
    /// participation filters, e.g. banded-MF min-separation, and — on
    /// scenario runs — the device-availability filter at the round's
    /// clock time, DESIGN.md §8). Returns the cohort plus the number of
    /// sampled train users skipped as unavailable (outside their
    /// diurnal window, or churned offline this round); 0 when the
    /// scenario layer is disabled, whose path is byte-identical to
    /// previous releases.
    fn sample_cohort(&self, ctx: &CentralContext) -> (Vec<usize>, u64) {
        let dataset = match ctx.population {
            Population::Train => &self.dataset,
            Population::Val => &self.val_dataset,
        };
        let mut cohort = if ctx.cohort_size > 0 {
            MinibatchSampler { cohort_size: ctx.cohort_size }.sample(
                dataset.num_users(),
                ctx.iteration,
                ctx.seed,
            )
        } else {
            self.sampler.sample(dataset.num_users(), ctx.iteration, ctx.seed)
        };
        let mut unavailable = 0u64;
        if ctx.population == Population::Train {
            // device availability first (an offline device is never even
            // asked), then the participation policies — the filter is a
            // pure function of (seed, uid, round), so every dispatch
            // mode and process sees the identical cohort
            if self.params.scenario.enabled() {
                let before = cohort.len();
                cohort.retain(|&uid| {
                    self.params.scenario.available(self.params.seed, uid, ctx.iteration)
                });
                unavailable = (before - cohort.len()) as u64;
            }
            cohort.retain(|&uid| {
                self.postprocessors.iter().all(|p| p.may_participate(uid, ctx.iteration))
            });
            for &uid in &cohort {
                for p in self.postprocessors.iter() {
                    p.record_participation(uid, ctx.iteration);
                }
            }
        }
        (cohort, unavailable)
    }

    /// Scheduling weight of one user: datapoint count, stretched by the
    /// device's speed-tier multiplier on scenario runs so slow devices
    /// sort as the stragglers they are (feeding greedy-LPT, the shared
    /// pull queue and the async heaviest-first order alike).
    fn scheduling_weight(&self, dataset: &Arc<dyn FederatedDataset>, uid: usize) -> f64 {
        let w = dataset.user_len(uid) as f64;
        if self.params.scenario.enabled() {
            w * self.params.scenario.speed_multiplier(self.params.seed, uid)
        } else {
            w
        }
    }

    /// Merge one worker result's bookkeeping into the outcome; returns
    /// the worker's busy nanos this command.
    fn absorb_result_bookkeeping(
        outcome: &mut RunOutcome,
        r: &super::worker::RoundResult,
    ) -> u64 {
        outcome.counters.merge(&r.counters);
        let busy: u64 = r.costs.iter().map(|c| c.nanos).sum();
        outcome.worker_busy_nanos[r.worker] += busy;
        // keep a bounded sample of user costs for Fig. 4a
        if outcome.user_costs.len() < 100_000 {
            outcome.user_costs.extend(&r.costs);
        }
        busy
    }

    /// Sample + dispatch + train one context's cohort (barrier on all
    /// workers), reduce the worker partials and apply the server-side
    /// postprocessors (reversed). Cohort distribution is delegated to
    /// the [`Dispatcher`]: owned LPT queues (Static) or a shared pull
    /// queue (WorkStealing; also Async's barrier phases).
    fn run_context(
        &self,
        ctx: &CentralContext,
        central: &[f32],
        server_rng: &mut Rng,
        outcome: &mut RunOutcome,
    ) -> Result<(Option<super::stats::Statistics>, Metrics)> {
        let dataset = match ctx.population {
            Population::Train => &self.dataset,
            Population::Val => &self.val_dataset,
        };
        let (cohort, unavailable) = self.sample_cohort(ctx);

        // --- cohort distribution (App. B.6 / dispatch.rs) ---------------
        let weights: Vec<f64> =
            cohort.iter().map(|&u| self.scheduling_weight(dataset, u)).collect();
        // an Async context reaching a barrier round (async eval/drain
        // phases) executes as a pull queue, the same mapping
        // dispatcher_for applies — so compare through it to reuse the
        // stored dispatcher instead of boxing a fresh one per round
        let effective_mode = match ctx.dispatch.mode {
            // barrier rounds of the async and distributed engines (eval,
            // drains) execute on the local pull queue
            DispatchMode::Async | DispatchMode::Socket => DispatchMode::WorkStealing,
            m => m,
        };
        let plan = if effective_mode == self.dispatcher.mode() {
            self.dispatcher.plan(&cohort, &weights, self.pool.num_workers)
        } else {
            dispatcher_for(ctx.dispatch, self.params.scheduler).plan(
                &cohort,
                &weights,
                self.pool.num_workers,
            )
        };
        let shared_queue = plan.shared;
        // feed the round's dispatch order to the prefetcher before any
        // worker asks for its first user (store-backed sources only)
        if self.source.wants_hints() {
            self.source.hint_round(&plan.dispatch_order());
        }
        let cache0 = StoreSnap::take(&outcome.counters);
        let dropped0 = outcome.counters.dropout_users;

        // --- distribute + train ----------------------------------------
        let central_arc = Arc::new(central.to_vec());
        let results = self.pool.run_round(ctx, central_arc, plan.sources)?;

        let mut metrics = Metrics::new();
        let mut partials = Vec::with_capacity(results.len());
        let mut worker_busy: Vec<u64> = Vec::with_capacity(results.len());
        let mut pulled: Vec<u64> = Vec::with_capacity(results.len());
        let mut round_stat_elements = 0u64;
        let mut round_stat_bytes = 0u64;
        for r in results {
            metrics.merge(&r.metrics);
            round_stat_elements += r.counters.stat_elements;
            round_stat_bytes += r.counters.stat_bytes;
            pulled.push(r.counters.users_trained);
            worker_busy.push(Self::absorb_result_bookkeeping(outcome, &r));
            if let Some(p) = r.partial {
                partials.push(p);
            }
        }
        // steal accounting covers training cohorts only, so the run-level
        // counter always equals the sum of the per-round metric
        if shared_queue && ctx.population == Population::Train {
            let steals = steal_count(&pulled);
            outcome.counters.steal_count += steals;
            metrics.add_central("sys/steal-count", steals as f64, 1.0);
        }
        if ctx.population == Population::Train {
            let gap = crate::simsys::straggler_gap_nanos(&worker_busy);
            outcome.straggler_nanos.push(gap);
            metrics.add_central("sys/straggler-secs", gap as f64 / 1e9, 1.0);
            metrics.add_central("sys/cohort", cohort.len() as f64, 1.0);
            // user→server wire volume this round, in f32-equivalents
            // (sparse updates count idx + val per nonzero) and in bytes
            // (which --quantize shrinks at unchanged element count)
            metrics.add_central("sys/user-update-elems", round_stat_elements as f64, 1.0);
            metrics.add_central("sys/user-update-bytes", round_stat_bytes as f64, 1.0);
            if self.params.scenario.enabled() {
                // barrier rounds dispatch the whole cohort, so the
                // hazard share is over the cohort and completion is its
                // complement (DESIGN.md §8); emitted only on scenario
                // runs so the disabled metric schema is unchanged
                let dropped = outcome.counters.dropout_users - dropped0;
                outcome.counters.unavailable_skipped += unavailable;
                metrics.add_central("sys/unavailable-skipped", unavailable as f64, 1.0);
                metrics.add_central(
                    "sys/dropout-frac",
                    dropped as f64 / cohort.len().max(1) as f64,
                    1.0,
                );
                metrics.add_central(
                    "sys/completion-rate",
                    (cohort.len() as u64).saturating_sub(dropped) as f64
                        / cohort.len().max(1) as f64,
                    1.0,
                );
            }
            store_metrics(&mut metrics, cache0, &outcome.counters);
        }

        // --- worker_reduce (all-reduce equivalent) ----------------------
        // serial left fold by default (byte-identical to previous
        // releases); parallel binary tree when opted in (--fold-tree)
        let mut agg = if self.params.fold_tree {
            let (agg, depth) = super::aggregator::tree_reduce(&*self.aggregator, partials);
            if ctx.population == Population::Train {
                metrics.add_central("sys/fold-tree-depth", depth as f64, 1.0);
            }
            agg
        } else {
            self.aggregator.worker_reduce(partials)
        };
        if ctx.population == Population::Train {
            if let Some(a) = agg.as_ref() {
                // stored f32s in the reduced aggregate: the full dense
                // length once any slot spilled, or the union nnz when an
                // all-sparse cohort stayed under the arena's spill
                // threshold (per-user communication is tracked
                // separately in sys/user-update-elems)
                metrics.add_central("sys/agg-elements", a.element_count() as f64, 1.0);
            }
        }

        // --- server postprocessors, reversed (paper Alg. 1 l.18) --------
        self.postprocess_server(agg.as_mut(), ctx, server_rng, &mut metrics, &mut outcome.counters)?;
        Ok((agg, metrics))
    }

    fn postprocess_server(
        &self,
        agg: Option<&mut super::stats::Statistics>,
        ctx: &CentralContext,
        server_rng: &mut Rng,
        metrics: &mut Metrics,
        counters: &mut Counters,
    ) -> Result<()> {
        if let Some(agg) = agg {
            let mut env = PpEnv {
                clip: &RustClip,
                rng: server_rng,
                user_len: 0,
                uid: 0,
                // the run seed is the counter engine's base key: every
                // round's noise streams derive from (seed, round), which
                // is what lets banded-MF regenerate past rounds' z's
                noise_key: self.params.seed,
                noise_threads: self.params.noise_threads,
                noise_nanos: 0,
            };
            for pp in self.postprocessors.iter().rev() {
                let pm = pp.postprocess_server(agg, ctx, &mut env)?;
                metrics.merge(&pm);
            }
            if env.noise_nanos > 0 {
                counters.noise_nanos += env.noise_nanos;
                metrics.add_central("sys/noise-nanos", env.noise_nanos as f64, 1.0);
            }
        }
        Ok(())
    }

    pub fn num_workers(&self) -> usize {
        self.pool.num_workers
    }

    /// The training dataset this backend simulates over (the generator,
    /// or the opened store for `--data-store` runs) — callers needing
    /// dataset metadata (e.g. central-eval shards) should reuse this
    /// rather than re-opening or re-building their own copy.
    pub fn dataset(&self) -> Arc<dyn FederatedDataset> {
        self.dataset.clone()
    }

    /// Coordinator traffic counters (baseline diagnostics).
    pub fn coordinator_traffic(&self) -> (u64, u64) {
        self.pool.coordinator_traffic()
    }
}

/// The arrival discipline of one async run: fold results in physical
/// arrival order (fastest), or in dispatch order through the bounded
/// reorder buffer (bit-identical across worker counts). Both share the
/// round loop in `run_async` and the fold step `fold_async_arrival`.
enum AsyncDriver {
    Physical(AsyncEngine),
    Replay(ReplayEngine),
}

/// Worker occupancy of the async engine: whether each worker has an
/// outstanding command (staleness is computed from `RoundResult::round`
/// on arrival, not stored here), plus the idle free-list.
struct AsyncEngine {
    inflight: Vec<bool>,
    idle: Vec<usize>,
}

/// One logically outstanding replay command: its dispatch sequence
/// number (the fold-order key) and the round it was dispatched in (the
/// deterministic staleness base).
#[derive(Debug, Clone, Copy)]
struct Outstanding {
    seq: u64,
    round: u64,
}

/// State of the deterministic-replay async engine
/// ([`SimulatedBackend::run_replay_train_context`]): the dispatch cursor, the
/// outstanding window in dispatch order, and the bounded
/// arrival-reorder buffer holding results that physically arrived ahead
/// of their fold turn.
#[derive(Default)]
struct ReplayEngine {
    next_seq: u64,
    outstanding: VecDeque<Outstanding>,
    parked: BTreeMap<u64, super::worker::RoundResult>,
}

/// One command in flight on the socket transport. Unlike the in-process
/// [`Outstanding`], it retains everything needed to *re-send* the
/// command verbatim (same seq → same fold order) if its worker dies.
struct SocketOutstanding {
    seq: u64,
    round: u64,
    uid: usize,
    /// The slot currently executing it (rewritten on requeue).
    worker: usize,
    ctx: CentralContext,
    central: Arc<Vec<f32>>,
}

/// State of the distributed replay engine
/// ([`SimulatedBackend::run_distributed`]): the dispatch cursor, the
/// outstanding window in dispatch order, the bounded arrival-reorder
/// buffer, and the run-level transport tallies behind
/// `sys/requeued-users` / `sys/worker-reconnects`.
#[derive(Default)]
struct SocketEngine {
    next_seq: u64,
    outstanding: VecDeque<SocketOutstanding>,
    parked: BTreeMap<u64, super::worker::RoundResult>,
    requeued_users: u64,
    reconnects: u64,
}

/// First live slot scanning from `seq % W`; errors only when every
/// connection is dead (nothing left to run the command).
fn socket_worker_for(pool: &SocketPool, seq: u64) -> Result<usize> {
    let w = pool.num_workers();
    let base = (seq % w as u64) as usize;
    for off in 0..w {
        let cand = (base + off) % w;
        if pool.alive(cand) {
            return Ok(cand);
        }
    }
    Err(anyhow!("no live workers left (all {w} socket connections are dead)"))
}

/// Round-start snapshot of the store-facing run counters; the deltas
/// against round end become the round's store `sys/` metrics.
#[derive(Debug, Clone, Copy)]
struct StoreSnap {
    hits: u64,
    misses: u64,
    bytes_read: u64,
    decode_nanos: u64,
    mmap_stall_nanos: u64,
    pread_stall_nanos: u64,
}

impl StoreSnap {
    fn take(c: &Counters) -> StoreSnap {
        StoreSnap {
            hits: c.cache_hits,
            misses: c.cache_misses,
            bytes_read: c.store_bytes_read,
            decode_nanos: c.decode_nanos,
            mmap_stall_nanos: c.mmap_stall_nanos,
            pread_stall_nanos: c.pread_stall_nanos,
        }
    }
}

/// Emit the per-round store metrics from the run-level counter deltas:
/// `sys/cache-hit-frac`, `sys/store-bytes-read` (true I/O — prefetched
/// bytes are credited when consumed), `sys/decode-nanos` (worker-side
/// decompression only; ≈0 means decode stayed on the prefetch thread)
/// and the miss-path stall split `sys/page-fault-stalls` (mmap) /
/// `sys/pread-stalls` (portable fallback), in seconds.
/// Generator-backed sources tick neither cache counter, so default runs
/// carry no store metrics at all.
fn store_metrics(metrics: &mut Metrics, before: StoreSnap, counters: &Counters) {
    let hits = counters.cache_hits - before.hits;
    let misses = counters.cache_misses - before.misses;
    if hits + misses == 0 {
        return;
    }
    metrics.add_central("sys/cache-hit-frac", hits as f64 / (hits + misses) as f64, 1.0);
    metrics.add_central(
        "sys/store-bytes-read",
        (counters.store_bytes_read - before.bytes_read) as f64,
        1.0,
    );
    metrics.add_central(
        "sys/decode-nanos",
        (counters.decode_nanos - before.decode_nanos) as f64,
        1.0,
    );
    metrics.add_central(
        "sys/page-fault-stalls",
        (counters.mmap_stall_nanos - before.mmap_stall_nanos) as f64 / 1e9,
        1.0,
    );
    metrics.add_central(
        "sys/pread-stalls",
        (counters.pread_stall_nanos - before.pread_stall_nanos) as f64 / 1e9,
        1.0,
    );
}

/// Fraction of the round's wall-clock the workers spent busy:
/// Σ measured per-worker busy / (workers × round wall). Clamped to
/// [0, 1] against measurement jitter.
fn busy_frac(busy_nanos: u64, round_nanos: u64, workers: usize) -> f64 {
    if round_nanos == 0 || workers == 0 {
        return 0.0;
    }
    (busy_nanos as f64 / (round_nanos as f64 * workers as f64)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::algorithm::{FedAvg, RunSpec};
    use crate::fl::central_opt::Sgd;
    use crate::fl::worker::tests::MeanModel;

    fn build_backend_with(workers: usize, iters: u64, dispatch: DispatchSpec) -> SimulatedBackend {
        let dataset: Arc<dyn FederatedDataset> =
            Arc::new(crate::data::SynthGmmPoints::new(32, 12, 3, 2, 1));
        let spec = RunSpec {
            iterations: iters,
            cohort_size: 8,
            val_cohort_size: 4,
            eval_every: 2,
            population: 32,
            ..Default::default()
        };
        let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
        BackendBuilder::new(
            dataset,
            alg,
            Arc::new(|_| Ok(Box::new(MeanModel::new(3)) as Box<dyn crate::fl::Model>)),
        )
        .params(RunParams { num_workers: workers, dispatch, ..Default::default() })
        .build()
        .unwrap()
    }

    fn build_backend(workers: usize, iters: u64) -> SimulatedBackend {
        build_backend_with(workers, iters, DispatchSpec::default())
    }

    /// Like [`build_backend_with`] but with full [`RunParams`] control, a
    /// configurable model dimension and a postprocessor chain.
    fn build_backend_cfg(
        iters: u64,
        dim: usize,
        params: RunParams,
        pps: Vec<Box<dyn Postprocessor>>,
    ) -> SimulatedBackend {
        let dataset: Arc<dyn FederatedDataset> =
            Arc::new(crate::data::SynthGmmPoints::new(32, 12, dim, 2, 1));
        let spec = RunSpec {
            iterations: iters,
            cohort_size: 8,
            val_cohort_size: 4,
            eval_every: 2,
            population: 32,
            ..Default::default()
        };
        let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
        let mut b = BackendBuilder::new(
            dataset,
            alg,
            Arc::new(move |_| Ok(Box::new(MeanModel::new(dim)) as Box<dyn crate::fl::Model>)),
        )
        .params(params);
        for pp in pps {
            b = b.postprocessor(pp);
        }
        b.build().unwrap()
    }

    #[test]
    fn run_completes_all_iterations() {
        let mut b = build_backend(2, 5);
        let out = b.run(vec![0.0; 3], &mut []).unwrap();
        assert_eq!(out.rounds, 5);
        assert_eq!(out.history.len(), 5);
        assert_eq!(out.round_nanos.len(), 5);
        assert!(out.counters.users_trained >= 5 * 8);
        assert!(out.final_metric("train/loss").is_some());
        // val rounds every 2 iterations
        assert!(out.final_metric("val/loss").is_some());
    }

    #[test]
    fn loss_decreases_on_mean_problem() {
        let mut b = build_backend(2, 30);
        let out = b.run(vec![5.0; 3], &mut []).unwrap();
        let series = out.series("train/loss");
        let first = series.first().unwrap().1;
        let last = series.last().unwrap().1;
        assert!(last < first * 0.9, "loss {first} -> {last}");
    }

    #[test]
    fn worker_count_does_not_change_learning() {
        // replica-worker invariance: final model identical across worker
        // counts (the sum aggregation is exchange-law compliant; MeanModel
        // arithmetic is deterministic).
        let out1 = build_backend(1, 6).run(vec![1.0; 3], &mut []).unwrap();
        let out4 = build_backend(4, 6).run(vec![1.0; 3], &mut []).unwrap();
        for (a, b) in out1.central.iter().zip(&out4.central) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn outcome_series_and_final_metric() {
        let mut b = build_backend(1, 4);
        let out = b.run(vec![0.0; 3], &mut []).unwrap();
        let series = out.series("sys/cohort");
        assert_eq!(series.len(), 4);
        assert_eq!(out.final_metric("sys/cohort"), Some(8.0));
        assert!(out.final_metric("does-not-exist").is_none());
    }

    #[test]
    fn busy_frac_formula_and_clamp() {
        assert_eq!(busy_frac(0, 0, 2), 0.0);
        assert_eq!(busy_frac(50, 100, 1), 0.5);
        assert_eq!(busy_frac(100, 100, 2), 0.5);
        // jitter can push measured busy past wall × workers: clamp
        assert_eq!(busy_frac(500, 100, 2), 1.0);
    }

    #[test]
    fn timeline_busy_frac_is_measured() {
        // satellite: busy_frac comes from per-worker busy nanos, not the
        // old hardcoded 0.0
        let mut b = build_backend(3, 5);
        let out = b.run(vec![0.0; 3], &mut []).unwrap();
        assert_eq!(out.timeline.rows.len(), 5);
        for row in &out.timeline.rows {
            assert!(
                row.busy_frac > 0.0 && row.busy_frac <= 1.0,
                "round {}: busy_frac {} not in (0, 1]",
                row.round,
                row.busy_frac
            );
        }
    }

    #[test]
    fn work_stealing_matches_static_learning() {
        // exchange-law invariance through the full loop: the pull queue
        // only moves users between workers, never changes the sum
        let out_static = build_backend(3, 6).run(vec![1.0; 3], &mut []).unwrap();
        let out_ws = build_backend_with(3, 6, DispatchSpec::work_stealing())
            .run(vec![1.0; 3], &mut [])
            .unwrap();
        assert_eq!(out_static.rounds, out_ws.rounds);
        for (a, b) in out_static.central.iter().zip(&out_ws.central) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // work-stealing rounds report the steal metric
        assert!(out_ws.final_metric("sys/steal-count").is_some());
    }

    #[test]
    fn fold_tree_matches_serial_and_reports_depth() {
        // opt-in tree fold reduces the same partials with a fixed
        // adjacent pairing: learning matches the serial left fold to f32
        // association tolerance, repeats are bit-identical, and the depth
        // metric reports ceil(log2(partials))
        let tree_run = || {
            build_backend_cfg(
                6,
                3,
                RunParams { num_workers: 4, fold_tree: true, ..Default::default() },
                vec![],
            )
            .run(vec![1.0; 3], &mut [])
            .unwrap()
        };
        let serial = build_backend(4, 6).run(vec![1.0; 3], &mut []).unwrap();
        let tree = tree_run();
        assert_eq!(serial.rounds, tree.rounds);
        for (a, b) in serial.central.iter().zip(&tree.central) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // 8 users over 4 workers: every worker ships a partial, depth 2
        assert_eq!(tree.final_metric("sys/fold-tree-depth"), Some(2.0));
        assert!(serial.final_metric("sys/fold-tree-depth").is_none());
        let tree2 = tree_run();
        assert_eq!(tree.central, tree2.central, "tree fold not deterministic");
    }

    #[test]
    fn noise_threads_do_not_change_learning() {
        // counter noise engine invariance through the full loop: the same
        // seed gives a bit-identical run for any thread count, and the
        // run reports noise time (sys/noise-nanos + Counters::noise_nanos)
        let run = |threads: usize| {
            build_backend_cfg(
                5,
                16,
                RunParams { num_workers: 2, noise_threads: threads, ..Default::default() },
                vec![Box::new(crate::privacy::GaussianMechanism::new(1.0, 0.5, 1.0))],
            )
            .run(vec![1.0; 16], &mut [])
            .unwrap()
        };
        let t1 = run(1);
        let t2 = run(2);
        let t4 = run(4);
        assert_eq!(t1.central, t2.central, "1 vs 2 noise threads diverged");
        assert_eq!(t1.central, t4.central, "1 vs 4 noise threads diverged");
        assert!(t1.counters.noise_nanos > 0, "noise time not accounted");
        assert!(t1.final_metric("sys/noise-nanos").is_some());
        // the legacy path still works and reports too — but draws a
        // different (stateful-stream) noise sequence
        let t0 = run(0);
        assert!(t0.counters.noise_nanos > 0);
        assert_ne!(t0.central, t1.central, "legacy and counter streams should differ");
    }

    #[test]
    fn wire_quantization_shrinks_update_bytes() {
        // acceptance: --quantize int8 drops sys/user-update-bytes >= 3.5x
        // vs none on the dense path, at unchanged element count and
        // near-identical learning
        let run = |pps: Vec<Box<dyn Postprocessor>>| {
            build_backend_cfg(4, 64, RunParams { num_workers: 2, ..Default::default() }, pps)
                .run(vec![1.0; 64], &mut [])
                .unwrap()
        };
        let base = run(vec![]);
        let q8 = run(vec![Box::new(super::super::postprocess::WireQuantizer::new(8, true))]);
        assert_eq!(base.counters.stat_elements, q8.counters.stat_elements);
        let ratio = base.counters.stat_bytes as f64 / q8.counters.stat_bytes as f64;
        assert!(ratio >= 3.5, "int8 wire bytes only {ratio:.2}x smaller");
        let m0 = base.final_metric("sys/user-update-bytes").unwrap();
        let m8 = q8.final_metric("sys/user-update-bytes").unwrap();
        assert!(m0 / m8 >= 3.5, "per-round bytes metric only {:.2}x smaller", m0 / m8);
        // the quantizer reports its round-trip error, and the decoded
        // aggregate still learns the same problem (int8 noise is small
        // relative to the update scale, not bit-identical)
        assert!(q8.final_metric("quant/err-l2").is_some());
        let q8_loss = q8.series("train/loss");
        let base_loss = base.series("train/loss");
        assert!(q8_loss.last().unwrap().1 < q8_loss.first().unwrap().1);
        let rel = (q8_loss.last().unwrap().1 - base_loss.last().unwrap().1).abs()
            / base_loss.last().unwrap().1.max(1e-9);
        assert!(rel < 0.1, "quantized final loss diverged {rel:.3} from exact");
    }

    #[test]
    fn async_completes_all_rounds_without_barrier() {
        // round count must be T regardless of worker count / stragglers
        let mut b = build_backend_with(4, 6, DispatchSpec::async_mode(2, 0.5));
        let out = b.run(vec![0.0; 3], &mut []).unwrap();
        assert_eq!(out.rounds, 6);
        assert_eq!(out.history.len(), 6);
        // every train round folded at least one arrival and advanced
        for (_, m) in &out.history {
            assert!(m.get("sys/async-folded").unwrap_or(0.0) >= 1.0);
        }
        // async pays no barrier: the recorded straggler gap is zero
        assert!(out.straggler_nanos.iter().all(|&g| g == 0));
        assert!(out.final_metric("train/loss").is_some());
        assert!(out.final_metric("val/loss").is_some());
    }

    #[test]
    fn async_is_deterministic_under_fixed_seed() {
        // satellite: with one worker the arrival order is the dispatch
        // order, so staleness weighting must be bit-deterministic
        let run = || {
            build_backend_with(1, 5, DispatchSpec::async_mode(2, 0.5))
                .run(vec![2.0; 3], &mut [])
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.central, b.central, "async run diverged under a fixed seed");
    }

    #[test]
    fn async_replay_bit_identical_across_worker_counts() {
        // the tentpole property: with the arrival-reorder buffer enabled
        // the async engine folds in dispatch order, so the entire run —
        // central model, fold/stale/drop accounting — is bit-identical
        // across worker counts (1, 2 and 4), not merely close.
        let run = |workers: usize| {
            build_backend_with(workers, 6, DispatchSpec::async_replay(2, 0.5, 4))
                .run(vec![2.0; 3], &mut [])
                .unwrap()
        };
        let (a, b, c) = (run(1), run(2), run(4));
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.rounds, c.rounds);
        assert_eq!(a.central, b.central, "1 vs 2 workers diverged");
        assert_eq!(a.central, c.central, "1 vs 4 workers diverged");
        assert_eq!(a.counters.stale_updates, b.counters.stale_updates);
        assert_eq!(a.counters.stale_updates, c.counters.stale_updates);
        assert_eq!(a.counters.dropped_updates, b.counters.dropped_updates);
        assert_eq!(a.counters.dropped_updates, c.counters.dropped_updates);
        for name in ["sys/async-folded", "sys/stale-updates", "sys/cohort"] {
            assert_eq!(a.series(name), b.series(name), "{name} series diverged (2 workers)");
            assert_eq!(a.series(name), c.series(name), "{name} series diverged (4 workers)");
        }
        // and repeating the same worker count is trivially identical too
        let a2 = run(1);
        assert_eq!(a.central, a2.central);
    }

    #[test]
    fn async_replay_still_learns_and_reports() {
        let mut b = build_backend_with(3, 20, DispatchSpec::async_replay(2, 0.5, 6));
        let out = b.run(vec![5.0; 3], &mut []).unwrap();
        assert_eq!(out.rounds, 20);
        let series = out.series("train/loss");
        assert!(series.last().unwrap().1 < series.first().unwrap().1);
        // the replay engine reports its outstanding window
        assert!(out.final_metric("sys/reorder-outstanding").is_some());
        assert!(out.final_metric("val/loss").is_some());
    }

    #[test]
    fn scenario_unset_is_byte_identical_and_silent() {
        // acceptance: with no scenario configured, every dispatch mode
        // runs exactly as before the device-realism layer existed — the
        // disabled spec short-circuits before touching any RNG stream, no
        // scenario metric appears in the schema, and both new counters
        // stay zero. An explicitly-disabled spec is the same as unset.
        for dispatch in [
            DispatchSpec::default(),
            DispatchSpec::work_stealing(),
            DispatchSpec::async_replay(2, 0.5, 4),
        ] {
            let run = |scenario: crate::fl::device::ScenarioSpec| {
                build_backend_cfg(
                    5,
                    3,
                    RunParams { num_workers: 2, dispatch, scenario, ..Default::default() },
                    vec![],
                )
                .run(vec![1.0; 3], &mut [])
                .unwrap()
            };
            let unset = run(Default::default());
            let off = run(crate::fl::device::ScenarioSpec::disabled());
            assert_eq!(unset.central, off.central, "disabled spec changed the run");
            assert_eq!(unset.history, off.history, "disabled spec changed the metrics");
            for out in [&unset, &off] {
                assert_eq!(out.counters.dropout_users, 0);
                assert_eq!(out.counters.unavailable_skipped, 0);
                for name in
                    ["sys/dropout-frac", "sys/unavailable-skipped", "sys/completion-rate"]
                {
                    assert!(
                        out.final_metric(name).is_none(),
                        "{name} leaked into a scenario-off run"
                    );
                }
            }
        }
    }

    #[test]
    fn scenario_dropout_bit_identical_across_worker_counts() {
        // headline property: availability and dropout draws are keyed by
        // (seed, uid, round) — never by worker streams — so a dropout-
        // afflicted async-replay run is bit-identical for 1, 2 and 4
        // workers: same central model, same per-round dropout deltas,
        // same completion curve.
        let scenario = crate::fl::device::ScenarioSpec {
            churn: 0.2,
            diurnal: 0.5,
            dropout_hazard: 0.3,
            speed_tiers: 3,
        };
        let run = |workers: usize| {
            build_backend_cfg(
                8,
                3,
                RunParams {
                    num_workers: workers,
                    dispatch: DispatchSpec::async_replay(2, 0.5, 4),
                    scenario,
                    ..Default::default()
                },
                vec![],
            )
            .run(vec![2.0; 3], &mut [])
            .unwrap()
        };
        let (a, b, c) = (run(1), run(2), run(4));
        assert!(a.counters.dropout_users > 0, "hazard 0.3 never fired");
        assert_eq!(a.central, b.central, "1 vs 2 workers diverged under dropout");
        assert_eq!(a.central, c.central, "1 vs 4 workers diverged under dropout");
        assert_eq!(a.counters.dropout_users, b.counters.dropout_users);
        assert_eq!(a.counters.dropout_users, c.counters.dropout_users);
        assert_eq!(a.counters.unavailable_skipped, b.counters.unavailable_skipped);
        assert_eq!(a.counters.unavailable_skipped, c.counters.unavailable_skipped);
        for name in
            ["sys/dropout-frac", "sys/unavailable-skipped", "sys/completion-rate", "sys/cohort"]
        {
            assert_eq!(a.series(name), b.series(name), "{name} diverged (2 workers)");
            assert_eq!(a.series(name), c.series(name), "{name} diverged (4 workers)");
        }
    }

    #[test]
    fn scenario_dropout_shrinks_rounds_but_still_learns() {
        // barrier path: dropped users are abandoned (partials discarded),
        // unavailable users never enter the cohort — yet the surviving
        // subset still solves the mean problem, and the three scenario
        // metrics account for every dispatched user.
        let scenario = crate::fl::device::ScenarioSpec {
            churn: 0.1,
            diurnal: 0.25,
            dropout_hazard: 0.2,
            speed_tiers: 2,
        };
        let out = build_backend_cfg(
            30,
            3,
            RunParams { num_workers: 2, scenario, ..Default::default() },
            vec![],
        )
        .run(vec![5.0; 3], &mut [])
        .unwrap();
        assert!(out.counters.dropout_users > 0, "hazard never fired in 30 rounds");
        assert!(out.counters.unavailable_skipped > 0, "diurnal+churn never excluded anyone");
        let completion = out.series("sys/completion-rate");
        assert_eq!(completion.len() as u64, out.rounds);
        for (t, v) in &completion {
            assert!((0.0..=1.0).contains(v), "round {t}: completion {v} out of range");
        }
        assert!(
            completion.iter().any(|(_, v)| *v < 1.0),
            "no round ever lost a user at hazard 0.2"
        );
        let series = out.series("train/loss");
        assert!(
            series.last().unwrap().1 < series.first().unwrap().1 * 0.9,
            "partial cohorts stopped learning"
        );
    }

    #[test]
    fn async_loss_still_decreases() {
        let mut b = build_backend_with(2, 30, DispatchSpec::async_mode(2, 0.5));
        let out = b.run(vec![5.0; 3], &mut []).unwrap();
        let series = out.series("train/loss");
        let first = series.first().unwrap().1;
        let last = series.last().unwrap().1;
        assert!(last < first, "async loss {first} -> {last}");
    }
}
