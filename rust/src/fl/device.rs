//! Per-user device realism: speed tiers, diurnal availability windows
//! on a simulated clock, and a per-round mid-round dropout hazard
//! (DESIGN.md §8).
//!
//! Every quantity here is a *pure function* of `(scenario, seed, uid,
//! round)` through the counter-based [`CtrRng`] (the PR 8 stateless
//! generator), so device behavior is bit-identical across worker
//! counts, dispatch modes, threads and processes, and independent of
//! query order. That purity is what lets the dropout-afflicted
//! async-replay engine stay bit-identical across 1/2/4 workers (see
//! `rust/tests/distributed.rs` and the backend determinism tests): no
//! draw ever flows through a worker-local or time-dependent stream.
//!
//! The scenario layer is **off by default** ([`ScenarioSpec::disabled`])
//! and every predicate short-circuits to its inert answer without
//! touching an RNG, so runs with the scenario unset execute the exact
//! code path they did before this layer existed.

use crate::util::rng::CtrRng;

/// Domain tag for the per-user profile stream ("DE71CE" ≈ DEVICE).
const PROFILE_TAG: u64 = 0xDE71_CE00_0000_0001;
/// Domain tag for per-(uid, round) churn draws (transient offline).
const CHURN_TAG: u64 = 0xDE71_CE00_0000_0002;
/// Domain tag for per-(uid, round) mid-round dropout draws.
const DROPOUT_TAG: u64 = 0xDE71_CE00_0000_0003;

/// Rounds per simulated day: the diurnal clock advances one central
/// round at a time and wraps every `ROUNDS_PER_DAY` rounds (15-minute
/// rounds on a 24 h day). Availability windows are expressed as
/// fractions of this day.
pub const ROUNDS_PER_DAY: u64 = 96;

/// Time-of-day for a central round, as a fraction of the day in [0, 1).
#[inline]
pub fn clock_frac(round: u64) -> f64 {
    (round % ROUNDS_PER_DAY) as f64 / ROUNDS_PER_DAY as f64
}

/// The scenario knobs (`scenario.{churn,diurnal,dropout_hazard,
/// speed_tiers}` in config, `--scenario` on the CLI). All-zero means
/// the layer is disabled and every existing run is byte-identical to
/// pre-scenario behavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioSpec {
    /// Mean per-round probability an otherwise-in-window user is
    /// transiently offline at cohort-sampling time (0 disables).
    pub churn: f64,
    /// Fraction of the simulated day each user is available (their
    /// window phase is sampled per uid). 0 or ≥ 1 disables the window.
    pub diurnal: f64,
    /// Mean per-round probability a dispatched user dies mid-round;
    /// its partial is discarded (DESIGN.md §8 policy table). 0 disables.
    pub dropout_hazard: f64,
    /// Number of device speed tiers; tier t runs 2^t× slower than tier
    /// 0. 0 or 1 means a uniform fleet.
    pub speed_tiers: u32,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec::disabled()
    }
}

/// One user's device profile, sampled deterministically from
/// `(seed, uid)` — bit-identical regardless of thread count, dispatch
/// mode, process boundary or query order (pinned by the golden fixture
/// in `rust/tests/fixtures/device_profiles_golden.txt`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    /// Speed tier in `0..speed_tiers` (0 = fastest).
    pub speed_tier: u32,
    /// Wall-clock cost multiplier: `2^speed_tier`.
    pub speed_multiplier: f64,
    /// Availability window start, as a fraction of the day in [0, 1).
    pub window_start: f64,
    /// Availability window length as a fraction of the day; 1.0 means
    /// always available (diurnal disabled).
    pub window_len: f64,
    /// This device's per-round mid-round dropout probability
    /// (heterogeneous around the scenario mean, clamped to [0, 1]).
    pub dropout_hazard: f64,
    /// This device's per-round transient-offline probability.
    pub churn_hazard: f64,
}

impl DeviceProfile {
    /// The inert profile used when the scenario layer is disabled.
    pub fn uniform() -> Self {
        DeviceProfile {
            speed_tier: 0,
            speed_multiplier: 1.0,
            window_start: 0.0,
            window_len: 1.0,
            dropout_hazard: 0.0,
            churn_hazard: 0.0,
        }
    }

    /// Whether time-of-day `t` (fraction of the day) falls inside this
    /// device's availability window, with wraparound past midnight.
    #[inline]
    pub fn in_window(&self, t: f64) -> bool {
        if self.window_len >= 1.0 {
            return true;
        }
        let end = self.window_start + self.window_len;
        if end <= 1.0 {
            t >= self.window_start && t < end
        } else {
            t >= self.window_start || t < end - 1.0
        }
    }
}

impl ScenarioSpec {
    /// The all-off spec: every predicate is inert and no RNG is drawn.
    pub fn disabled() -> Self {
        ScenarioSpec {
            churn: 0.0,
            diurnal: 0.0,
            dropout_hazard: 0.0,
            speed_tiers: 0,
        }
    }

    /// Whether any scenario knob is active.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.churn > 0.0 || self.diurnal > 0.0 || self.dropout_hazard > 0.0 || self.speed_tiers > 1
    }

    /// Parse the CLI form: comma-separated `key=value` pairs, e.g.
    /// `churn=0.1,diurnal=0.5,dropout=0.05,tiers=3`. Accepted keys:
    /// `churn`, `diurnal`, `dropout` / `dropout_hazard`, `tiers` /
    /// `speed_tiers`. `off` yields the disabled spec.
    pub fn parse(s: &str) -> Result<ScenarioSpec, String> {
        let mut spec = ScenarioSpec::disabled();
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(spec);
        }
        for pair in s.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("scenario: expected key=value, got '{pair}'"))?;
            let (k, v) = (k.trim(), v.trim());
            let frac = |v: &str| -> Result<f64, String> {
                let x: f64 = v
                    .parse()
                    .map_err(|_| format!("scenario: '{v}' is not a number (key '{k}')"))?;
                if !(0.0..=1.0).contains(&x) {
                    return Err(format!("scenario: {k}={v} outside [0, 1]"));
                }
                Ok(x)
            };
            match k {
                "churn" => spec.churn = frac(v)?,
                "diurnal" => spec.diurnal = frac(v)?,
                "dropout" | "dropout_hazard" => spec.dropout_hazard = frac(v)?,
                "tiers" | "speed_tiers" => {
                    spec.speed_tiers = v
                        .parse()
                        .map_err(|_| format!("scenario: '{v}' is not a tier count"))?
                }
                other => return Err(format!("scenario: unknown key '{other}'")),
            }
        }
        Ok(spec)
    }

    /// Sample user `uid`'s device profile — a pure function of
    /// `(self, seed, uid)`; same inputs give bit-identical output on
    /// any thread, in any order.
    pub fn profile(&self, seed: u64, uid: usize) -> DeviceProfile {
        if !self.enabled() {
            return DeviceProfile::uniform();
        }
        let rng = CtrRng::new(seed ^ PROFILE_TAG, uid as u64);
        let speed_tier = if self.speed_tiers > 1 {
            (rng.u64_at(0) % self.speed_tiers as u64) as u32
        } else {
            0
        };
        let speed_multiplier = (1u64 << speed_tier.min(62)) as f64;
        let (window_start, window_len) = if self.diurnal > 0.0 && self.diurnal < 1.0 {
            (rng.f64_at(1), self.diurnal)
        } else {
            (0.0, 1.0)
        };
        // Heterogeneous hazards: uniform on [0, 2·mean] (mean preserved),
        // clamped into probability range.
        let dropout_hazard = (self.dropout_hazard * 2.0 * rng.f64_at(2)).clamp(0.0, 1.0);
        let churn_hazard = (self.churn * 2.0 * rng.f64_at(3)).clamp(0.0, 1.0);
        DeviceProfile {
            speed_tier,
            speed_multiplier,
            window_start,
            window_len,
            dropout_hazard,
            churn_hazard,
        }
    }

    /// Whether `uid` can be sampled into round `round`'s cohort: inside
    /// its diurnal window at the round's clock time and not churned
    /// offline this round. Deterministic in `(self, seed, uid, round)`.
    pub fn available(&self, seed: u64, uid: usize, round: u64) -> bool {
        if !self.enabled() {
            return true;
        }
        let p = self.profile(seed, uid);
        if !p.in_window(clock_frac(round)) {
            return false;
        }
        if p.churn_hazard > 0.0
            && CtrRng::new(seed ^ CHURN_TAG, uid as u64).f64_at(round) < p.churn_hazard
        {
            return false;
        }
        true
    }

    /// Whether `uid` dies mid-round in `round` after being dispatched
    /// (its partial is discarded and never folded). Deterministic in
    /// `(self, seed, uid, round)` — crucially *not* in which worker ran
    /// it or when, so thread and socket transports agree bit-for-bit.
    pub fn drops_out(&self, seed: u64, uid: usize, round: u64) -> bool {
        if !self.enabled() {
            return false;
        }
        let p = self.profile(seed, uid);
        p.dropout_hazard > 0.0
            && CtrRng::new(seed ^ DROPOUT_TAG, uid as u64).f64_at(round) < p.dropout_hazard
    }

    /// The wall-clock cost multiplier for `uid` (1.0 when disabled).
    #[inline]
    pub fn speed_multiplier(&self, seed: u64, uid: usize) -> f64 {
        if !self.enabled() || self.speed_tiers <= 1 {
            return 1.0;
        }
        self.profile(seed, uid).speed_multiplier
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn golden_spec() -> ScenarioSpec {
        ScenarioSpec {
            churn: 0.2,
            diurnal: 0.5,
            dropout_hazard: 0.1,
            speed_tiers: 3,
        }
    }

    fn profile_bits(p: &DeviceProfile) -> [u64; 6] {
        [
            p.speed_tier as u64,
            p.speed_multiplier.to_bits(),
            p.window_start.to_bits(),
            p.window_len.to_bits(),
            p.dropout_hazard.to_bits(),
            p.churn_hazard.to_bits(),
        ]
    }

    #[test]
    fn disabled_spec_is_inert() {
        let spec = ScenarioSpec::disabled();
        assert!(!spec.enabled());
        assert_eq!(spec, ScenarioSpec::default());
        for uid in 0..64 {
            assert_eq!(spec.profile(7, uid), DeviceProfile::uniform());
            assert_eq!(spec.speed_multiplier(7, uid), 1.0);
            for round in 0..200 {
                assert!(spec.available(7, uid, round));
                assert!(!spec.drops_out(7, uid, round));
            }
        }
    }

    #[test]
    fn profiles_are_pure_functions_of_seed_and_uid() {
        // Same (seed, uid) must give bit-identical profiles regardless
        // of query order or thread — the property the whole scenario
        // layer's cross-dispatcher determinism rests on.
        let spec = golden_spec();
        let forward: Vec<_> = (0..256).map(|u| spec.profile(42, u)).collect();
        let reverse: Vec<_> = (0..256).rev().map(|u| spec.profile(42, u)).collect();
        for u in 0..256 {
            assert_eq!(
                profile_bits(&forward[u]),
                profile_bits(&reverse[255 - u]),
                "uid {u}: query order changed the profile"
            );
        }
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let want = forward.clone();
                std::thread::spawn(move || {
                    // each thread walks uids in a different stride order
                    for i in 0..256usize {
                        let u = (i * (t * 2 + 1)) % 256;
                        let got = spec.profile(42, u);
                        assert_eq!(
                            profile_bits(&got),
                            profile_bits(&want[u]),
                            "thread {t} uid {u}"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn seed_and_uid_separate_streams() {
        let spec = golden_spec();
        let a = spec.profile(42, 3);
        assert_ne!(profile_bits(&a), profile_bits(&spec.profile(43, 3)));
        assert_ne!(profile_bits(&a), profile_bits(&spec.profile(42, 4)));
    }

    #[test]
    fn profile_fields_lie_in_contracted_ranges() {
        let spec = golden_spec();
        for uid in 0..512 {
            let p = spec.profile(11, uid);
            assert!(p.speed_tier < spec.speed_tiers, "uid {uid}");
            assert_eq!(p.speed_multiplier, (1u64 << p.speed_tier) as f64);
            assert!((0.0..1.0).contains(&p.window_start), "uid {uid}");
            assert_eq!(p.window_len, spec.diurnal);
            assert!((0.0..=2.0 * spec.dropout_hazard).contains(&p.dropout_hazard));
            assert!((0.0..=2.0 * spec.churn).contains(&p.churn_hazard));
        }
    }

    #[test]
    fn window_membership_handles_wraparound() {
        let mut p = DeviceProfile::uniform();
        p.window_start = 0.75;
        p.window_len = 0.5; // covers [0.75, 1.0) ∪ [0.0, 0.25)
        assert!(p.in_window(0.8));
        assert!(p.in_window(0.0));
        assert!(p.in_window(0.2));
        assert!(!p.in_window(0.25));
        assert!(!p.in_window(0.5));
        assert!(!p.in_window(0.74));
        p.window_len = 1.0;
        assert!(p.in_window(0.5));
    }

    #[test]
    fn clock_is_periodic_and_in_range() {
        for r in 0..3 * ROUNDS_PER_DAY {
            let t = clock_frac(r);
            assert!((0.0..1.0).contains(&t));
            assert_eq!(t, clock_frac(r + ROUNDS_PER_DAY));
        }
        assert_eq!(clock_frac(0), 0.0);
    }

    #[test]
    fn availability_tracks_window_fraction() {
        // Over whole days, a pure-diurnal spec (no churn) admits each
        // user for exactly its window's share of rounds.
        let spec = ScenarioSpec {
            diurnal: 0.25,
            ..ScenarioSpec::disabled()
        };
        for uid in 0..32 {
            let avail = (0..ROUNDS_PER_DAY)
                .filter(|&r| spec.available(5, uid, r))
                .count() as f64
                / ROUNDS_PER_DAY as f64;
            assert!(
                (avail - 0.25).abs() < 2.0 / ROUNDS_PER_DAY as f64,
                "uid {uid}: available {avail}"
            );
        }
    }

    #[test]
    fn dropout_frequency_tracks_hazard() {
        let spec = ScenarioSpec {
            dropout_hazard: 0.2,
            ..ScenarioSpec::disabled()
        };
        let rounds = 4000u64;
        let mut drops = 0usize;
        for uid in 0..16 {
            let h = spec.profile(9, uid).dropout_hazard;
            let got = (0..rounds).filter(|&r| spec.drops_out(9, uid, r)).count();
            let want = h * rounds as f64;
            assert!(
                (got as f64 - want).abs() < 0.05 * rounds as f64,
                "uid {uid}: {got} drops vs hazard {h}"
            );
            drops += got;
        }
        assert!(drops > 0);
    }

    #[test]
    fn parse_round_trips_and_rejects_junk() {
        let s = ScenarioSpec::parse("churn=0.2,diurnal=0.5,dropout=0.1,tiers=3").unwrap();
        assert_eq!(s, golden_spec());
        let s = ScenarioSpec::parse("speed_tiers=2, dropout_hazard=0.05").unwrap();
        assert_eq!(s.speed_tiers, 2);
        assert_eq!(s.dropout_hazard, 0.05);
        assert_eq!(ScenarioSpec::parse("off").unwrap(), ScenarioSpec::disabled());
        assert_eq!(ScenarioSpec::parse("").unwrap(), ScenarioSpec::disabled());
        assert!(ScenarioSpec::parse("churn=2.0").is_err());
        assert!(ScenarioSpec::parse("bogus=1").is_err());
        assert!(ScenarioSpec::parse("churn").is_err());
        assert!(ScenarioSpec::parse("tiers=x").is_err());
    }

    #[test]
    fn golden_fixture_of_32_profiles_is_stable() {
        // Pins the profile sampling against finalizer drift: the
        // fixture was generated from this exact CtrRng derivation
        // (seed 42, uids 0..32, churn=0.2 diurnal=0.5 dropout=0.1
        // tiers=3) with every f64 stored as its raw bit pattern.
        let fixture = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/rust/tests/fixtures/device_profiles_golden.txt"
        ))
        .expect("golden fixture missing");
        let spec = golden_spec();
        let mut uids = 0;
        for line in fixture.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            assert_eq!(f.len(), 7, "fixture line: '{line}'");
            let uid: usize = f[0].parse().unwrap();
            let want = [
                f[1].parse::<u64>().unwrap(),
                u64::from_str_radix(f[2], 16).unwrap(),
                u64::from_str_radix(f[3], 16).unwrap(),
                u64::from_str_radix(f[4], 16).unwrap(),
                u64::from_str_radix(f[5], 16).unwrap(),
                u64::from_str_radix(f[6], 16).unwrap(),
            ];
            let got = spec.profile(42, uid);
            assert_eq!(
                profile_bits(&got),
                want,
                "uid {uid}: profile drifted from golden fixture ({got:?})"
            );
            uids += 1;
        }
        assert_eq!(uids, 32, "fixture must pin exactly 32 profiles");
    }
}
