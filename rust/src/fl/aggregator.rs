//! Aggregation, decoupled from algorithms (paper App. B.2).
//!
//! An aggregator is a pair of operations:
//! * `accumulate` (f): fold one user's statistics into a worker-local
//!   partial state, and
//! * `worker_reduce` (g): combine the partial states of all workers.
//!
//! They must satisfy the paper's exchange law
//!     g({f(Sa, Δ), Sb}) = g({f(Sb, Δ), Sa}) = f(g({Sa, Sb}), Δ)
//! so that the result is independent of how users are scheduled across
//! workers. `property_invariants.rs` checks this with randomized inputs
//! for every aggregator we ship.

use super::stats::Statistics;

pub trait Aggregator: Send + Sync {
    /// Fold one user's statistics into the worker-local accumulator.
    fn accumulate(&self, acc: &mut Option<Statistics>, user: Statistics);

    /// Combine worker partials (all-reduce equivalent; in-process this is
    /// a tree reduce over the worker results).
    fn worker_reduce(&self, partials: Vec<Statistics>) -> Option<Statistics>;

    /// Fold one contribution scaled by `scale` — the staleness-weighted
    /// fold of async buffered aggregation (see
    /// [`crate::fl::dispatch::staleness_weight`]). Both the vectors and
    /// the aggregation weight **must** scale together, or the weighted
    /// -average denominator over-counts stale users (regression-pinned
    /// in `accumulate_scaled_weight_denominator_regression` below): a
    /// half-weighted update contributes half a user.
    fn accumulate_scaled(&self, acc: &mut Option<Statistics>, mut user: Statistics, scale: f32) {
        if scale != 1.0 {
            for v in user.vecs.values_mut() {
                v.scale(scale);
            }
            user.weight *= scale as f64;
        }
        self.accumulate(acc, user);
    }

    /// True when `accumulate` is a plain pointwise sum, so the worker
    /// may fold user statistics into its resident
    /// [`crate::tensor::StatsArena`] buffers by reference instead of
    /// moving per-user `Vec`s — the allocation-free hot path. Aggregators
    /// with other semantics (e.g. [`CollectAggregator`]) keep the
    /// move-based `accumulate` path.
    fn arena_compatible(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Parallel deterministic binary tree reduction over worker partials
/// (`RunParams::fold_tree` / `--fold-tree`): fixed adjacent pairing
/// (0,1)(2,3)… repeated until one partial remains, each level's
/// pairwise merges running concurrently on scoped threads.
///
/// The pairing is a pure function of the partial count, so the fold
/// order — and therefore the f32 rounding — is reproducible run to run
/// at any parallelism. It differs from the serial left fold in general
/// (tree (a+b)+(c+d) vs serial ((a+b)+c)+d), which is why the tree is
/// opt-in and the default serial [`Aggregator::worker_reduce`] stays
/// byte-identical to pre-tree behavior. Each pairwise merge is the
/// aggregator's own binary `worker_reduce`, reusing the partials'
/// buffers (the left operand absorbs the right), so no model-sized
/// temporaries beyond the partials themselves are allocated.
///
/// Returns the reduced statistics plus the tree depth (⌈log₂ n⌉; 0 for
/// n ≤ 1), surfaced as the `sys/fold-tree-depth` metric.
pub fn tree_reduce(
    agg: &dyn Aggregator,
    partials: Vec<Statistics>,
) -> (Option<Statistics>, u32) {
    let mut layer = partials;
    let mut depth = 0u32;
    while layer.len() > 1 {
        depth += 1;
        let mut pairs: Vec<(Statistics, Option<Statistics>)> =
            Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.drain(..);
        while let Some(a) = it.next() {
            pairs.push((a, it.next()));
        }
        drop(it);
        let merged: Vec<Statistics> = std::thread::scope(|s| {
            let handles: Vec<_> = pairs
                .into_iter()
                .map(|(a, b)| {
                    s.spawn(move || match b {
                        Some(b) => agg
                            .worker_reduce(vec![a, b])
                            .expect("binary reduce of two partials yields Some"),
                        // odd tail passes through to the next level
                        None => a,
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("tree-fold merge panicked"))
                .collect()
        });
        layer = merged;
    }
    (layer.pop(), depth)
}

/// Vector summation — the FL default: f(S, Δ) = S + Δ, g = Σ.
#[derive(Debug, Default, Clone)]
pub struct SumAggregator;

impl Aggregator for SumAggregator {
    fn accumulate(&self, acc: &mut Option<Statistics>, user: Statistics) {
        match acc {
            None => *acc = Some(user),
            Some(state) => {
                state.weight += user.weight;
                for (key, v) in user.vecs {
                    match state.vecs.get_mut(&key) {
                        Some(dst) => dst.add_value(&v),
                        None => {
                            state.vecs.insert(key, v);
                        }
                    }
                }
            }
        }
    }

    fn worker_reduce(&self, partials: Vec<Statistics>) -> Option<Statistics> {
        let mut acc = None;
        for p in partials {
            self.accumulate(&mut acc, p);
        }
        acc
    }

    /// Sparse-aware scaled fold: discounts a stale arrival directly into
    /// the accumulator (`axpy` / `scatter_axpy`) instead of scaling a
    /// copy first, and never densifies a sparse contribution the plain
    /// sum would have kept sparse. The weight scales with the values —
    /// the denominator contract of the default implementation.
    fn accumulate_scaled(&self, acc: &mut Option<Statistics>, mut user: Statistics, scale: f32) {
        if scale == 1.0 {
            return self.accumulate(acc, user);
        }
        match acc {
            None => {
                for v in user.vecs.values_mut() {
                    v.scale(scale);
                }
                user.weight *= scale as f64;
                *acc = Some(user);
            }
            Some(state) => {
                state.weight += user.weight * scale as f64;
                for (key, v) in user.vecs {
                    match state.vecs.get_mut(&key) {
                        Some(dst) => dst.axpy_value(scale, &v),
                        None => {
                            let mut v = v;
                            v.scale(scale);
                            state.vecs.insert(key, v);
                        }
                    }
                }
            }
        }
    }

    fn arena_compatible(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "sum"
    }
}

/// Set-union collection (paper App. B.2's second example): gathers every
/// user's statistics individually. Useful for research on per-update
/// inspection; vectors are stored under unique keys.
#[derive(Debug, Default, Clone)]
pub struct CollectAggregator;

impl Aggregator for CollectAggregator {
    fn accumulate(&self, acc: &mut Option<Statistics>, user: Statistics) {
        let state = acc.get_or_insert_with(Statistics::default);
        state.weight += user.weight;
        let idx = state.vecs.len();
        for (key, v) in user.vecs {
            state.vecs.insert(format!("{key}#{idx}"), v);
        }
    }

    fn worker_reduce(&self, partials: Vec<Statistics>) -> Option<Statistics> {
        let mut out: Option<Statistics> = None;
        for p in partials {
            let state = out.get_or_insert_with(Statistics::default);
            state.weight += p.weight;
            let base = state.vecs.len();
            for (i, (key, v)) in p.vecs.into_iter().enumerate() {
                // re-key to keep entries unique across workers
                let orig = key.split('#').next().unwrap_or(&key).to_string();
                state.vecs.insert(format!("{orig}#{}", base + i), v);
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "collect"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(v: Vec<f32>, w: f64) -> Statistics {
        Statistics::new_update(v, w)
    }

    #[test]
    fn sum_accumulates_and_reduces() {
        let agg = SumAggregator;
        let mut acc = None;
        agg.accumulate(&mut acc, stat(vec![1.0, 2.0], 1.0));
        agg.accumulate(&mut acc, stat(vec![3.0, 4.0], 2.0));
        let a = acc.unwrap();
        assert_eq!(a.update(), &[4.0, 6.0]);
        assert_eq!(a.weight, 3.0);

        let reduced = agg
            .worker_reduce(vec![a, stat(vec![1.0, 1.0], 1.0)])
            .unwrap();
        assert_eq!(reduced.update(), &[5.0, 7.0]);
        assert_eq!(reduced.weight, 4.0);
    }

    #[test]
    fn sum_exchange_law_simple() {
        let agg = SumAggregator;
        let sa = stat(vec![1.0, 0.0], 1.0);
        let sb = stat(vec![0.0, 1.0], 1.0);
        let d = stat(vec![2.0, 2.0], 1.0);

        // g({f(Sa, Δ), Sb})
        let mut left = Some(sa.clone());
        agg.accumulate(&mut left, d.clone());
        let left = agg.worker_reduce(vec![left.unwrap(), sb.clone()]).unwrap();

        // f(g({Sa, Sb}), Δ)
        let mut right = agg.worker_reduce(vec![sa, sb]);
        agg.accumulate(&mut right, d);
        let right = right.unwrap();

        assert_eq!(left.update(), right.update());
        assert_eq!(left.weight, right.weight);
    }

    #[test]
    fn sum_handles_disjoint_keys() {
        let agg = SumAggregator;
        let mut a = stat(vec![1.0], 1.0);
        a.insert("extra", vec![5.0]);
        let b = stat(vec![2.0], 1.0);
        let r = agg.worker_reduce(vec![a, b]).unwrap();
        assert_eq!(r.update(), &[3.0]);
        assert_eq!(r.get("extra").unwrap(), &[5.0]);
    }

    #[test]
    fn collect_keeps_individuals() {
        let agg = CollectAggregator;
        let mut acc = None;
        agg.accumulate(&mut acc, stat(vec![1.0], 1.0));
        agg.accumulate(&mut acc, stat(vec![2.0], 1.0));
        let a = acc.unwrap();
        assert_eq!(a.vecs.len(), 2);
        let r = agg
            .worker_reduce(vec![a, {
                let mut acc2 = None;
                agg.accumulate(&mut acc2, stat(vec![3.0], 1.0));
                acc2.unwrap()
            }])
            .unwrap();
        assert_eq!(r.vecs.len(), 3);
        assert_eq!(r.weight, 3.0);
    }

    #[test]
    fn accumulate_scaled_discounts_vectors_and_weight() {
        let agg = SumAggregator;
        let mut acc = None;
        agg.accumulate_scaled(&mut acc, stat(vec![2.0, 4.0], 1.0), 1.0);
        agg.accumulate_scaled(&mut acc, stat(vec![2.0, 4.0], 1.0), 0.5);
        let a = acc.unwrap();
        assert_eq!(a.update(), &[3.0, 6.0]);
        assert_eq!(a.weight, 1.5);
        // scaled average equals the unscaled user's update: the discount
        // shrinks the *influence*, not the direction
        let mut avg = a.clone();
        avg.average_in_place();
        assert_eq!(avg.update(), &[2.0, 4.0]);
    }

    #[test]
    fn accumulate_scaled_weight_denominator_regression() {
        // async-fold weight accounting (ISSUE 4 satellite): the scaled
        // fold must discount the *weight* together with the values, or
        // the weighted-average denominator over-counts stale users.
        // Hand-computed two-user case, user B stale by one round
        // (staleness weight 0.5):
        //   sum   = 1.0·2.0·[0.5, 1.5] + 0.5·4.0·[2.0, 1.0] = [5.0, 5.0]
        //   denom = 1.0·2.0 + 0.5·4.0 = 4.0     (NOT 2.0 + 4.0 = 6.0)
        //   avg   = [1.25, 1.25]
        let agg = SumAggregator;
        let mut acc = None;
        agg.accumulate_scaled(&mut acc, stat(vec![1.0, 3.0], 2.0), 1.0);
        agg.accumulate_scaled(&mut acc, stat(vec![8.0, 4.0], 4.0), 0.5);
        let mut a = acc.unwrap();
        assert_eq!(a.weight, 4.0, "denominator must discount the stale user");
        assert_eq!(a.update(), &[5.0, 5.0]);
        a.average_in_place();
        assert_eq!(a.update(), &[1.25, 1.25]);
    }

    #[test]
    fn accumulate_scaled_keeps_sparse_sparse() {
        use crate::fl::stats::StatValue;
        let agg = SumAggregator;
        // sparse + scaled sparse stays sparse (no densify in the async
        // fold), and values discount exactly
        let mut acc = None;
        agg.accumulate_scaled(
            &mut acc,
            Statistics::new_update_value(StatValue::sparse(8, vec![1], vec![4.0]), 1.0),
            1.0,
        );
        agg.accumulate_scaled(
            &mut acc,
            Statistics::new_update_value(StatValue::sparse(8, vec![1, 6], vec![2.0, 8.0]), 2.0),
            0.5,
        );
        let a = acc.unwrap();
        let v = a.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }), "async fold densified: {v:?}");
        assert_eq!(v.to_dense_vec(), vec![0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 4.0, 0.0]);
        assert_eq!(a.weight, 2.0);

        // scaled sparse into a dense accumulator scatters in place
        let mut acc = Some(stat(vec![1.0; 4], 1.0));
        agg.accumulate_scaled(
            &mut acc,
            Statistics::new_update_value(StatValue::sparse(4, vec![0, 3], vec![2.0, -2.0]), 1.0),
            0.25,
        );
        let a = acc.unwrap();
        assert_eq!(a.update(), &[1.5, 1.0, 1.0, 0.5]);
        assert_eq!(a.weight, 1.25);
    }

    #[test]
    fn empty_reduce_is_none() {
        assert!(SumAggregator.worker_reduce(vec![]).is_none());
        assert!(CollectAggregator.worker_reduce(vec![]).is_none());
    }

    #[test]
    fn tree_reduce_handles_degenerate_counts() {
        let agg = SumAggregator;
        let (none, depth) = tree_reduce(&agg, vec![]);
        assert!(none.is_none());
        assert_eq!(depth, 0);
        let (one, depth) = tree_reduce(&agg, vec![stat(vec![1.0, 2.0], 3.0)]);
        assert_eq!(one.unwrap().update(), &[1.0, 2.0]);
        assert_eq!(depth, 0);
    }

    #[test]
    fn tree_reduce_matches_serial_bit_exact_on_exact_inputs() {
        // powers of two sum exactly in f32, so tree and serial fold
        // orders agree to the bit for any partial count (incl. odd)
        let agg = SumAggregator;
        for n in [2usize, 3, 4, 5, 7, 8, 16] {
            let partials: Vec<Statistics> = (0..n)
                .map(|w| stat(vec![(1 << w.min(20)) as f32, 0.5, -2.0], 1.0 + w as f64))
                .collect();
            let serial = agg.worker_reduce(partials.clone()).unwrap();
            let (tree, depth) = tree_reduce(&agg, partials);
            let tree = tree.unwrap();
            assert_eq!(tree.update(), serial.update(), "n={n}");
            assert_eq!(tree.weight, serial.weight, "n={n}");
            assert_eq!(depth, (n as f64).log2().ceil() as u32, "n={n}");
        }
    }

    #[test]
    fn tree_reduce_is_deterministic_across_repeats() {
        let agg = SumAggregator;
        let partials: Vec<Statistics> = (0..6)
            .map(|w| stat((0..64).map(|i| ((w * 64 + i) as f32).sin()).collect(), 1.0))
            .collect();
        let (a, _) = tree_reduce(&agg, partials.clone());
        let (b, _) = tree_reduce(&agg, partials);
        assert_eq!(a.unwrap().update(), b.unwrap().update(), "tree fold order must be fixed");
    }

    #[test]
    fn tree_reduce_keeps_all_sparse_sparse() {
        use crate::fl::stats::StatValue;
        let agg = SumAggregator;
        let partials: Vec<Statistics> = (0..4)
            .map(|w| {
                Statistics::new_update_value(
                    StatValue::sparse(16, vec![w as u32 * 3], vec![1.0 + w as f32]),
                    1.0,
                )
            })
            .collect();
        let (r, depth) = tree_reduce(&agg, partials);
        let r = r.unwrap();
        let v = r.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }), "tree fold densified: {v:?}");
        assert_eq!(v.element_count(), 4);
        assert_eq!(depth, 2);
    }

    #[test]
    fn tree_reduce_collect_keeps_every_entry() {
        let agg = CollectAggregator;
        let partials: Vec<Statistics> = (0..5)
            .map(|w| {
                let mut acc = None;
                agg.accumulate(&mut acc, stat(vec![w as f32], 1.0));
                acc.unwrap()
            })
            .collect();
        let (r, _) = tree_reduce(&agg, partials);
        let r = r.unwrap();
        assert_eq!(r.vecs.len(), 5);
        assert_eq!(r.weight, 5.0);
    }

    #[test]
    fn sum_mixes_sparse_and_dense() {
        use crate::fl::stats::StatValue;
        let agg = SumAggregator;
        let mut acc = None;
        agg.accumulate(&mut acc, stat(vec![1.0, 0.0, 1.0], 1.0));
        agg.accumulate(
            &mut acc,
            Statistics::new_update_value(StatValue::sparse(3, vec![1], vec![5.0]), 1.0),
        );
        let a = acc.unwrap();
        assert_eq!(a.update(), &[1.0, 5.0, 1.0]);
        assert_eq!(a.weight, 2.0);

        // all-sparse stays sparse through the reduce
        let s1 = Statistics::new_update_value(StatValue::sparse(4, vec![0], vec![1.0]), 1.0);
        let s2 = Statistics::new_update_value(StatValue::sparse(4, vec![2], vec![2.0]), 1.0);
        let r = agg.worker_reduce(vec![s1, s2]).unwrap();
        let v = r.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }));
        assert_eq!(v.to_dense_vec(), vec![1.0, 0.0, 2.0, 0.0]);
    }
}
