//! Pull-based round dispatch: how a sampled cohort reaches the worker
//! replicas.
//!
//! The paper's distributed deployment (App. B.6) pre-computes per-worker
//! assignments because its worker *processes* cannot cheaply pull user
//! ids from a central queue; static greedy LPT scheduling recovers ~19%
//! on FLAIR. Our workers are in-process replica threads, so that
//! constraint does not apply and the dispatcher becomes a pluggable
//! policy with three modes ([`crate::fl::context::DispatchMode`]):
//!
//! * **Static** — the paper-faithful design: [`super::scheduler`] packs
//!   the cohort into owned per-worker queues, the backend barriers on
//!   all workers. Keep this for baseline comparisons (Tables 1–2, 5) and
//!   for the virtual-cluster replay, whose roofline model assumes
//!   precomputed queues.
//! * **WorkStealing** — an extension the paper's architecture cannot
//!   express: one shared [`CohortQueue`] in LPT order, consumed through
//!   an atomic cursor. No per-cohort assignment allocation, and the
//!   measured straggler gap (`sys/straggler-secs`) collapses to at most
//!   one user's tail because a worker that finishes early keeps pulling.
//! * **Async** — staleness-bounded buffered aggregation (FedBuff-style;
//!   also an extension — none of the frameworks the paper compares
//!   simulate it). Workers stream per-user statistics; the server folds
//!   the first K arrivals weighted by [`staleness_weight`] and opens the
//!   next context without waiting for stragglers. The async engine lives
//!   in `backend::run_async`; this module supplies its drain/eval plans.
//!   With `DispatchSpec::reorder_window > 0` the engine switches to
//!   **deterministic replay**: at most `window` commands stay logically
//!   outstanding and their results fold strictly in dispatch
//!   (round, uid) order through a bounded arrival-reorder buffer, so
//!   the run — folds, staleness discounts, drops, central updates — is
//!   bit-identical across worker counts (property-tested in
//!   `backend.rs`).
//!
//! Statistics invariance: under an exchange-law aggregator (e.g.
//! `SumAggregator`) Static and WorkStealing produce identical reduced
//! statistics — only *which worker* folds a user changes, never the sum
//! (property-tested in this module and in `worker.rs`). This holds even
//! with per-user DP postprocessors because the worker derives their RNG
//! from (run seed, context seed, uid), never from a worker-thread
//! stream — the thread race over the pull queue cannot leak into the
//! noise. Async changes
//! the learning dynamics by design (partial cohorts, staleness
//! discounts) and is therefore *not* paper-faithful; it opens a workload
//! class, not a faster path to the same numbers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::context::{DispatchMode, DispatchSpec};
use super::scheduler::{order, schedule, SchedulerKind};

/// A shared pull queue over one cohort: user ids in dispatch order,
/// consumed lock-free through an atomic cursor. Cloning the `Arc` hands
/// the same queue to every worker.
#[derive(Debug)]
pub struct CohortQueue {
    users: Vec<usize>,
    cursor: AtomicUsize,
}

impl CohortQueue {
    pub fn new(users: Vec<usize>) -> Self {
        CohortQueue { users, cursor: AtomicUsize::new(0) }
    }

    /// Claim the next user id, or `None` once the cohort is exhausted.
    pub fn pop(&self) -> Option<usize> {
        // Relaxed is enough: the slot index is the only shared state and
        // fetch_add makes each index claimed exactly once; `users` is
        // immutable and published by the channel send of the command.
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.users.get(i).copied()
    }

    pub fn len(&self) -> usize {
        self.users.len()
    }

    pub fn is_empty(&self) -> bool {
        self.users.is_empty()
    }

    /// Users not yet claimed (approximate under concurrency; used only
    /// as a capacity hint).
    pub fn remaining(&self) -> usize {
        self.users.len().saturating_sub(self.cursor.load(Ordering::Relaxed))
    }

    /// The full queue in claim order (the cursor does not reorder it) —
    /// the prefetcher's upcoming-uid feed for shared-queue rounds.
    pub fn ordered(&self) -> &[usize] {
        &self.users
    }
}

/// One worker's work for one round: an owned queue (static schedule) or
/// a shared pull queue (work-stealing / async drain).
pub enum WorkSource {
    Owned(Vec<usize>),
    Shared(Arc<CohortQueue>),
}

impl WorkSource {
    /// Capacity hint for per-user bookkeeping: exact for owned queues,
    /// 0 for shared queues (a shared source *could* yield the whole
    /// remaining cohort, but reserving that much in every worker would
    /// allocate W× the cohort; amortized Vec growth is cheaper).
    pub fn len_hint(&self) -> usize {
        match self {
            WorkSource::Owned(v) => v.len(),
            WorkSource::Shared(_) => 0,
        }
    }

    /// Convert into a draining pull iterator.
    pub fn into_pull(self) -> WorkIter {
        match self {
            WorkSource::Owned(v) => WorkIter::Owned(v.into_iter()),
            WorkSource::Shared(q) => WorkIter::Shared(q),
        }
    }
}

/// Draining iterator over a [`WorkSource`]; for shared sources every
/// `next` is a fresh claim against the cohort-wide cursor.
pub enum WorkIter {
    Owned(std::vec::IntoIter<usize>),
    Shared(Arc<CohortQueue>),
}

impl Iterator for WorkIter {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        match self {
            WorkIter::Owned(it) => it.next(),
            WorkIter::Shared(q) => q.pop(),
        }
    }
}

/// The per-cohort distribution produced by a [`Dispatcher`].
pub struct DispatchPlan {
    /// One source per worker, in worker order.
    pub sources: Vec<WorkSource>,
    /// True when the sources share one pull queue (enables steal
    /// accounting in the backend).
    pub shared: bool,
}

impl DispatchPlan {
    /// The order the round will consume users in — the upcoming-uid
    /// feed for [`crate::data::UserDataSource::hint_round`]. Shared
    /// plans consume their one queue in cursor order; owned plans run
    /// W queues concurrently, so their feed interleaves the per-worker
    /// queues round-robin (each worker's next user stays near the
    /// front, whichever worker asks next).
    pub fn dispatch_order(&self) -> Vec<usize> {
        if self.shared {
            if let Some(WorkSource::Shared(q)) = self.sources.first() {
                return q.ordered().to_vec();
            }
        }
        let queues: Vec<&[usize]> = self
            .sources
            .iter()
            .filter_map(|s| match s {
                WorkSource::Owned(v) => Some(v.as_slice()),
                WorkSource::Shared(_) => None,
            })
            .collect();
        let total: usize = queues.iter().map(|q| q.len()).sum();
        let mut out = Vec::with_capacity(total);
        let mut depth = 0;
        while out.len() < total {
            for q in &queues {
                if let Some(&uid) = q.get(depth) {
                    out.push(uid);
                }
            }
            depth += 1;
        }
        out
    }
}

/// Cohort distribution policy: turns (cohort, weights) into per-worker
/// work sources. Consumes [`super::scheduler`] as the ordering policy.
pub trait Dispatcher: Send + Sync {
    fn name(&self) -> &'static str;

    fn mode(&self) -> DispatchMode;

    /// Distribute one cohort across `num_workers` workers. `weights[i]`
    /// is the scheduling weight of `cohort[i]`.
    fn plan(&self, cohort: &[usize], weights: &[f64], num_workers: usize) -> DispatchPlan;
}

/// Paper-faithful static dispatch: greedy LPT packing into owned
/// per-worker queues (App. B.6).
pub struct StaticDispatcher {
    pub scheduler: SchedulerKind,
}

impl Dispatcher for StaticDispatcher {
    fn name(&self) -> &'static str {
        "static"
    }

    fn mode(&self) -> DispatchMode {
        DispatchMode::Static
    }

    fn plan(&self, cohort: &[usize], weights: &[f64], num_workers: usize) -> DispatchPlan {
        let sched = schedule(self.scheduler, weights, num_workers);
        let sources = sched
            .assignments
            .iter()
            .map(|idxs| WorkSource::Owned(idxs.iter().map(|&i| cohort[i]).collect()))
            .collect();
        DispatchPlan { sources, shared: false }
    }
}

/// Pull-based dispatch: one shared queue in scheduler order, every
/// worker claims users until the cohort is dry.
pub struct WorkStealingDispatcher {
    pub scheduler: SchedulerKind,
}

impl Dispatcher for WorkStealingDispatcher {
    fn name(&self) -> &'static str {
        "work-stealing"
    }

    fn mode(&self) -> DispatchMode {
        DispatchMode::WorkStealing
    }

    fn plan(&self, cohort: &[usize], weights: &[f64], num_workers: usize) -> DispatchPlan {
        let users: Vec<usize> =
            order(self.scheduler, weights).into_iter().map(|i| cohort[i]).collect();
        let q = Arc::new(CohortQueue::new(users));
        let sources = (0..num_workers.max(1)).map(|_| WorkSource::Shared(q.clone())).collect();
        DispatchPlan { sources, shared: true }
    }
}

/// The dispatcher implementing a [`DispatchSpec`]. `Async` maps to the
/// pull-queue dispatcher: the async engine (`backend::run_async`) drives
/// its own per-user streaming and uses this plan only for the barrier
/// phases it still needs (federated eval, drains). `Socket` likewise:
/// the distributed engine (`backend::run_distributed`) streams
/// seq-stamped commands over [`crate::comms`] itself and falls back to
/// the local pull queue only for federated eval on the server.
pub fn dispatcher_for(spec: DispatchSpec, scheduler: SchedulerKind) -> Box<dyn Dispatcher> {
    match spec.mode {
        DispatchMode::Static => Box::new(StaticDispatcher { scheduler }),
        DispatchMode::WorkStealing | DispatchMode::Async | DispatchMode::Socket => {
            Box::new(WorkStealingDispatcher { scheduler })
        }
    }
}

/// FedBuff-style staleness discount for an update that lags the current
/// round by `staleness` iterations: 1/(1+s). Pure in `s`, so async
/// aggregation is deterministic given the arrival order.
pub fn staleness_weight(staleness: u64) -> f32 {
    1.0 / (1.0 + staleness as f32)
}

/// Steal accounting for a shared-queue round: given per-worker pull
/// counts, the number of users pulled beyond the even ⌈n/w⌉ share — the
/// load the pull queue migrated relative to a uniform split (0 when the
/// cohort happens to divide evenly across equally-fast workers).
pub fn steal_count(pulled: &[u64]) -> u64 {
    if pulled.is_empty() {
        return 0;
    }
    let n: u64 = pulled.iter().sum();
    let share = n.div_ceil(pulled.len() as u64);
    pulled.iter().map(|&p| p.saturating_sub(share)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_pops_each_user_once() {
        let q = CohortQueue::new(vec![7, 8, 9]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.remaining(), 3);
        let mut seen = vec![q.pop(), q.pop(), q.pop()];
        seen.sort();
        assert_eq!(seen, vec![Some(7), Some(8), Some(9)]);
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None); // stays exhausted
        assert_eq!(q.remaining(), 0);
    }

    #[test]
    fn queue_is_unique_under_concurrency() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let q = Arc::new(CohortQueue::new((0..1000).collect()));
        let seen = Arc::new(Mutex::new(HashSet::new()));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                while let Some(u) = q.pop() {
                    assert!(seen.lock().unwrap().insert(u), "user {u} claimed twice");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(seen.lock().unwrap().len(), 1000);
    }

    #[test]
    fn static_plan_partitions_the_cohort() {
        let cohort = vec![10, 11, 12, 13, 14];
        let weights = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        let plan = StaticDispatcher { scheduler: SchedulerKind::Greedy }.plan(&cohort, &weights, 2);
        assert!(!plan.shared);
        assert_eq!(plan.sources.len(), 2);
        let mut all: Vec<usize> = plan
            .sources
            .into_iter()
            .flat_map(|s| match s {
                WorkSource::Owned(v) => v,
                WorkSource::Shared(_) => panic!("static plan must own its queues"),
            })
            .collect();
        all.sort();
        assert_eq!(all, cohort);
    }

    #[test]
    fn worksteal_plan_shares_one_lpt_queue() {
        let cohort = vec![10, 11, 12];
        let weights = vec![1.0, 9.0, 5.0];
        let plan =
            WorkStealingDispatcher { scheduler: SchedulerKind::Greedy }.plan(&cohort, &weights, 3);
        assert!(plan.shared);
        assert_eq!(plan.sources.len(), 3);
        let q = match &plan.sources[0] {
            WorkSource::Shared(q) => q.clone(),
            WorkSource::Owned(_) => panic!("worksteal plan must share"),
        };
        // heaviest first
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), Some(12));
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), None);
        // the other sources drain the same (now exhausted) queue
        assert_eq!(q.remaining(), 0);
        // shared sources never reserve cohort-sized bookkeeping
        assert_eq!(plan.sources[1].len_hint(), 0);
    }

    #[test]
    fn dispatch_order_covers_the_cohort_for_both_plans() {
        let cohort = vec![10, 11, 12, 13, 14];
        let weights = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        let sp = StaticDispatcher { scheduler: SchedulerKind::Greedy }.plan(&cohort, &weights, 2);
        let order = sp.dispatch_order();
        assert_eq!(order.len(), cohort.len());
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(sorted, cohort, "static order must cover the cohort exactly once");
        // the first W entries are the workers' first pulls
        let heads: Vec<usize> = sp
            .sources
            .iter()
            .filter_map(|s| match s {
                WorkSource::Owned(v) => v.first().copied(),
                WorkSource::Shared(_) => None,
            })
            .collect();
        assert_eq!(&order[..heads.len()], &heads[..]);

        let wp =
            WorkStealingDispatcher { scheduler: SchedulerKind::Greedy }.plan(&cohort, &weights, 2);
        // shared plans feed the queue's claim order (LPT: heaviest first)
        assert_eq!(wp.dispatch_order(), vec![10, 11, 12, 13, 14]);
    }

    #[test]
    fn dispatcher_for_maps_modes() {
        let k = SchedulerKind::Greedy;
        assert_eq!(dispatcher_for(DispatchSpec::default(), k).mode(), DispatchMode::Static);
        assert_eq!(
            dispatcher_for(DispatchSpec::work_stealing(), k).mode(),
            DispatchMode::WorkStealing
        );
        // async uses the pull queue for its barrier phases
        assert_eq!(
            dispatcher_for(DispatchSpec::async_mode(2, 0.5), k).mode(),
            DispatchMode::WorkStealing
        );
        // socket mode evals on the server's local pull queue
        assert_eq!(
            dispatcher_for(DispatchSpec::socket(2, 0.5, 4), k).mode(),
            DispatchMode::WorkStealing
        );
    }

    #[test]
    fn staleness_weight_decays_from_one() {
        assert_eq!(staleness_weight(0), 1.0);
        assert_eq!(staleness_weight(1), 0.5);
        assert!(staleness_weight(2) < staleness_weight(1));
    }

    #[test]
    fn steal_count_measures_imbalance() {
        assert_eq!(steal_count(&[]), 0);
        assert_eq!(steal_count(&[3, 3, 3]), 0); // even split
        assert_eq!(steal_count(&[4, 3, 2]), 1); // 9 users / 3 -> share 3
        assert_eq!(steal_count(&[9, 0, 0]), 6);
        assert_eq!(steal_count(&[4, 3]), 0); // 7 users / 2 -> share 4
    }

    #[test]
    fn worksteal_matches_static_reduction() {
        // Exchange-law extension of `pool_result_independent_of_worker
        // _count`: pulling from a shared queue must produce the same
        // reduced statistics as the precomputed LPT assignment.
        use crate::data::FederatedDataset;
        use crate::fl::aggregator::Aggregator;
        use crate::fl::context::CentralContext;
        use crate::fl::worker::tests::mean_pool;

        let data: std::sync::Arc<dyn FederatedDataset> =
            std::sync::Arc::new(crate::data::SynthGmmPoints::new(12, 10, 2, 2, 3));
        let cohort: Vec<usize> = (0..12).collect();
        let weights: Vec<f64> = cohort.iter().map(|&u| data.user_len(u) as f64).collect();
        let ctx = CentralContext::train(0, 12, Default::default(), 1);
        let agg = crate::fl::SumAggregator;

        let mut reduced = Vec::new();
        for dispatcher in [
            Box::new(StaticDispatcher { scheduler: SchedulerKind::Greedy }) as Box<dyn Dispatcher>,
            Box::new(WorkStealingDispatcher { scheduler: SchedulerKind::Greedy }),
        ] {
            let pool = mean_pool(3, 2, data.clone());
            let plan = dispatcher.plan(&cohort, &weights, pool.num_workers);
            let results = pool
                .run_round(&ctx, std::sync::Arc::new(vec![0.0; 2]), plan.sources)
                .unwrap();
            let trained: u64 = results.iter().map(|r| r.counters.users_trained).sum();
            assert_eq!(trained, 12, "{} trained the wrong user count", dispatcher.name());
            let partials: Vec<_> = results.into_iter().filter_map(|r| r.partial).collect();
            reduced.push(agg.worker_reduce(partials).unwrap());
            pool.shutdown().unwrap();
        }
        let (a, b) = (&reduced[0], &reduced[1]);
        assert_eq!(a.weight, b.weight);
        for (x, y) in a.update().iter().zip(b.update()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
}
