//! Metrics with the paper's two aggregation semantics (App. B.4):
//!
//! * **Central** metrics — clients contribute aggregable *sufficient
//!   statistics* (sum + weight); the metric is `sum / weight` after
//!   aggregation. The right choice for central-model quality (accuracy
//!   over all datapoints, perplexity over all tokens).
//! * **Per-user** metrics — each client produces a finished value; the
//!   aggregate is the mean over clients. The right choice for
//!   personalization-style questions ("how many users do well").
//!
//! The worked example from App. B.4 is a unit test below.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MetricValue {
    Central { sum: f64, weight: f64 },
    PerUser { sum: f64, count: u64 },
}

impl MetricValue {
    pub fn central(sum: f64, weight: f64) -> Self {
        MetricValue::Central { sum, weight }
    }

    pub fn per_user(value: f64) -> Self {
        MetricValue::PerUser { sum: value, count: 1 }
    }

    /// The finished scalar value of the metric.
    pub fn value(&self) -> f64 {
        match self {
            MetricValue::Central { sum, weight } => {
                if *weight == 0.0 {
                    0.0
                } else {
                    sum / weight
                }
            }
            MetricValue::PerUser { sum, count } => {
                if *count == 0 {
                    0.0
                } else {
                    sum / *count as f64
                }
            }
        }
    }

    /// Merge two contributions of the same metric. Mixing central and
    /// per-user semantics is a contract violation reported as a typed
    /// [`MetricError`] — never a panic: one malformed user metric must
    /// not abort a simulation round (see [`Metrics::add`], which skips
    /// the offending contribution and counts it).
    pub fn try_merge(&mut self, other: &MetricValue) -> Result<(), MetricError> {
        match (self, other) {
            (
                MetricValue::Central { sum: s, weight: w },
                MetricValue::Central { sum: os, weight: ow },
            ) => {
                *s += os;
                *w += ow;
                Ok(())
            }
            (
                MetricValue::PerUser { sum: s, count: c },
                MetricValue::PerUser { sum: os, count: oc },
            ) => {
                *s += os;
                *c += oc;
                Ok(())
            }
            (a, b) => Err(MetricError::KindMismatch { left: *a, right: *b }),
        }
    }
}

/// Typed metric-pipeline error (the fold/merge paths used to panic on
/// these).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MetricError {
    /// A central and a per-user contribution met under one metric name.
    KindMismatch { left: MetricValue, right: MetricValue },
}

impl fmt::Display for MetricError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MetricError::KindMismatch { left, right } => {
                write!(f, "metric kind mismatch: {left:?} vs {right:?}")
            }
        }
    }
}

impl std::error::Error for MetricError {}

/// Name under which skipped kind-mismatched contributions are counted
/// (value = total count; summed across merges with a pinned weight).
pub const KIND_MISMATCH_METRIC: &str = "sys/metric-kind-mismatch";

/// An ordered bag of named metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics(pub BTreeMap<String, MetricValue>);

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one contribution. On a kind mismatch the incoming
    /// contribution is **skipped and counted** under
    /// [`KIND_MISMATCH_METRIC`] (first writer wins) — a malformed user
    /// metric degrades one reading, not the whole simulation. Callers
    /// that want the strict contract use [`MetricValue::try_merge`]
    /// directly.
    pub fn add(&mut self, name: impl Into<String>, v: MetricValue) {
        let name = name.into();
        if name.ends_with(KIND_MISMATCH_METRIC) {
            // the mismatch counter is a plain total: contributions —
            // including prefixed copies from namespaced eval bags
            // (`prefixed("val/")`) — fold into the one unprefixed
            // counter with the weight pinned at 1, so `get` returns the
            // total rather than a per-bag average and
            // `kind_mismatches()` sees every skip
            if let MetricValue::Central { sum, .. } = v {
                self.bump_mismatch(sum);
            }
            return;
        }
        match self.0.get_mut(&name) {
            Some(existing) => {
                if existing.try_merge(&v).is_err() {
                    self.bump_mismatch(1.0);
                }
            }
            None => {
                self.0.insert(name, v);
            }
        }
    }

    fn bump_mismatch(&mut self, n: f64) {
        match self.0.get_mut(KIND_MISMATCH_METRIC) {
            Some(MetricValue::Central { sum, .. }) => *sum += n,
            _ => {
                self.0.insert(KIND_MISMATCH_METRIC.into(), MetricValue::central(n, 1.0));
            }
        }
    }

    /// Contributions skipped because of a metric kind mismatch.
    pub fn kind_mismatches(&self) -> u64 {
        self.get(KIND_MISMATCH_METRIC).unwrap_or(0.0) as u64
    }

    pub fn add_central(&mut self, name: impl Into<String>, sum: f64, weight: f64) {
        self.add(name, MetricValue::central(sum, weight));
    }

    pub fn add_per_user(&mut self, name: impl Into<String>, value: f64) {
        self.add(name, MetricValue::per_user(value));
    }

    /// Overwrite (no merge) — for already-finished values like timings.
    pub fn set(&mut self, name: impl Into<String>, v: MetricValue) {
        self.0.insert(name.into(), v);
    }

    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.0 {
            self.add(k.clone(), *v);
        }
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.0.get(name).map(|v| v.value())
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.0.keys().map(|s| s.as_str())
    }

    /// A copy with every name prefixed (the backend namespaces federated
    /// evaluation rounds as `val/...`).
    pub fn prefixed(&self, prefix: &str) -> Metrics {
        Metrics(
            self.0
                .iter()
                .map(|(k, v)| (format!("{prefix}{k}"), *v))
                .collect(),
        )
    }
}

/// Macro-averaged average precision over `labels` binary labels — the
/// FLAIR benchmark's mAP ("C-AP" in [79]). `scores` and `targets` are
/// row-major [n, labels]; labels with no positive example are skipped.
pub fn mean_average_precision(scores: &[f32], targets: &[f32], labels: usize) -> f64 {
    if labels == 0 || scores.is_empty() {
        return 0.0;
    }
    let n = scores.len() / labels;
    let mut ap_sum = 0.0;
    let mut ap_count = 0usize;
    let mut order: Vec<usize> = Vec::with_capacity(n);
    for l in 0..labels {
        order.clear();
        order.extend(0..n);
        order.sort_by(|&a, &b| {
            scores[b * labels + l]
                .partial_cmp(&scores[a * labels + l])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut tp = 0u64;
        let mut precision_sum = 0.0;
        for (rank, &i) in order.iter().enumerate() {
            if targets[i * labels + l] > 0.5 {
                tp += 1;
                precision_sum += tp as f64 / (rank + 1) as f64;
            }
        }
        if tp > 0 {
            ap_sum += precision_sum / tp as f64;
            ap_count += 1;
        }
    }
    if ap_count == 0 {
        0.0
    } else {
        ap_sum / ap_count as f64
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (k, v) in &self.0 {
            if !first {
                write!(f, " ")?;
            }
            write!(f, "{k}={:.5}", v.value())?;
            first = false;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact worked example from paper App. B.4: U1 has 1 datapoint
    /// (all correct), U2 has 7 (all wrong).
    #[test]
    fn paper_example_central_vs_per_user() {
        let mut m = Metrics::new();
        // U1
        m.add_central("acc/central", 1.0, 1.0);
        m.add_per_user("acc/per-user", 1.0 / 1.0);
        // U2
        m.add_central("acc/central", 0.0, 7.0);
        m.add_per_user("acc/per-user", 0.0 / 7.0);

        assert!((m.get("acc/per-user").unwrap() - 0.5).abs() < 1e-12);
        assert!((m.get("acc/central").unwrap() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let contribs: Vec<Metrics> = (0..4)
            .map(|i| {
                let mut m = Metrics::new();
                m.add_central("loss", i as f64, 2.0);
                m.add_per_user("score", i as f64 * 0.1);
                m
            })
            .collect();

        let mut forward = Metrics::new();
        for c in &contribs {
            forward.merge(c);
        }
        let mut backward = Metrics::new();
        for c in contribs.iter().rev() {
            backward.merge(c);
        }
        for name in ["loss", "score"] {
            let f = forward.get(name).unwrap();
            let b = backward.get(name).unwrap();
            assert!((f - b).abs() < 1e-12, "{name}: {f} vs {b}");
        }
        assert!((forward.get("loss").unwrap() - (0.0 + 1.0 + 2.0 + 3.0) / 8.0).abs() < 1e-12);
        assert!((forward.get("score").unwrap() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn zero_weight_is_zero_not_nan() {
        let mut m = Metrics::new();
        m.add_central("x", 0.0, 0.0);
        assert_eq!(m.get("x").unwrap(), 0.0);
    }

    #[test]
    fn kind_mismatch_is_skipped_and_counted_not_a_panic() {
        // regression (ISSUE 4 satellite): a malformed user metric used to
        // panic mid-round; now the contribution is skipped, the first
        // writer wins, and the skip is observable
        let mut m = Metrics::new();
        m.add_central("x", 1.0, 1.0);
        m.add_per_user("x", 9.0);
        assert_eq!(m.get("x"), Some(1.0), "first writer must win");
        assert_eq!(m.kind_mismatches(), 1);
        m.add_per_user("x", 9.0);
        assert_eq!(m.kind_mismatches(), 2);

        // the typed error carries both sides for diagnostics
        let mut a = MetricValue::central(1.0, 1.0);
        let err = a.try_merge(&MetricValue::per_user(2.0)).unwrap_err();
        assert!(format!("{err}").contains("kind mismatch"));
    }

    #[test]
    fn mismatch_counter_sums_across_bag_merges() {
        // two worker bags each with one skip: the merged bag reports the
        // total, not a per-bag average
        let bag = || {
            let mut m = Metrics::new();
            m.add_central("x", 1.0, 1.0);
            m.add_per_user("x", 1.0);
            m
        };
        let (a, b) = (bag(), bag());
        assert_eq!(a.kind_mismatches(), 1);
        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.kind_mismatches(), 2);
        // the real metric merged normally
        assert_eq!(merged.get("x"), Some(1.0));

        // a namespaced copy (the backend prefixes eval bags "val/")
        // still folds into the one total instead of averaging under the
        // prefixed name
        let mut with_val = Metrics::new();
        with_val.merge(&a);
        with_val.merge(&b.prefixed("val/"));
        assert_eq!(with_val.kind_mismatches(), 2);
        assert!(with_val.get("val/sys/metric-kind-mismatch").is_none());
    }

    #[test]
    fn display_formats() {
        let mut m = Metrics::new();
        m.add_central("a", 1.0, 2.0);
        let s = format!("{m}");
        assert!(s.contains("a=0.5"));
    }

    #[test]
    fn prefixed_renames() {
        let mut m = Metrics::new();
        m.add_central("loss", 2.0, 1.0);
        let p = m.prefixed("val/");
        assert_eq!(p.get("val/loss"), Some(2.0));
        assert!(p.get("loss").is_none());
    }

    #[test]
    fn map_perfect_ranking_is_one() {
        // 3 examples, 2 labels; scores rank positives first everywhere
        let scores = [0.9, 0.1, 0.8, 0.9, 0.1, 0.2];
        let targets = [1.0, 0.0, 1.0, 1.0, 0.0, 0.0];
        let map = mean_average_precision(&scores, &targets, 2);
        assert!((map - 1.0).abs() < 1e-12, "{map}");
    }

    #[test]
    fn map_worst_ranking_below_one() {
        let scores = [0.1, 0.9, 0.8];
        let targets = [1.0, 0.0, 0.0];
        // positive ranked last of 3 -> AP = 1/3
        let map = mean_average_precision(&scores, &targets, 1);
        assert!((map - 1.0 / 3.0).abs() < 1e-12, "{map}");
    }

    #[test]
    fn map_empty_inputs() {
        assert_eq!(mean_average_precision(&[], &[], 0), 0.0);
        // no positives at all
        assert_eq!(mean_average_precision(&[0.5, 0.5], &[0.0, 0.0], 1), 0.0);
    }
}
