//! Postprocessors (paper App. B.1 "Postprocessor"): composable
//! transformations of local statistics before aggregation and of the
//! aggregate before the central update. DP mechanisms, weighting,
//! sparsification and compression all plug in here, so they mix and match
//! with any algorithm.
//!
//! Ordering matters (paper: server-side steps run in *reversed* order;
//! DP clipping must be the last local step so nothing changes the
//! sensitivity afterwards). The backend enforces the reversed-server
//! convention; configs list postprocessors in local-application order.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

use super::context::CentralContext;
use super::metrics::Metrics;
use super::model::ClipKernel;
use super::stats::{StatValue, Statistics};
use crate::tensor::ops;
use crate::util::rng::{round_key, CtrRng, Rng};

/// Execution environment handed to a postprocessor: the calling side's
/// clip kernel (the worker's L1 Pallas artifact on the user path, a pure
/// Rust implementation on the server path) and a deterministic RNG stream.
pub struct PpEnv<'a> {
    pub clip: &'a dyn ClipKernel,
    pub rng: &'a mut Rng,
    /// Number of datapoints of the user being processed (0 on the server
    /// path) — the input to weighting policies.
    pub user_len: usize,
    /// Id of the user being processed (0 on the server path) — the key
    /// for per-user state such as [`WireQuantizer`] error-feedback
    /// residuals, which must survive the user being re-dispatched to a
    /// different worker in a later round.
    pub uid: usize,
    /// Run-level base key for the counter-based noise engine. Mechanisms
    /// derive per-round streams via [`PpEnv::ctr`]; carrying the *base*
    /// (not a per-round key) lets banded-MF regenerate past rounds'
    /// noise from `(base, round)` alone.
    pub noise_key: u64,
    /// Worker threads for counter-based noise kernels. 0 selects the
    /// legacy sequential `env.rng` path (byte-identical to pre-engine
    /// output); N ≥ 1 selects the counter engine, whose output is
    /// bit-identical for every N.
    pub noise_threads: usize,
    /// Wall-clock nanoseconds spent generating DP noise this call chain;
    /// accumulated by mechanisms, drained into `Counters::noise_nanos`
    /// and the `sys/noise-nanos` metric by the caller.
    pub noise_nanos: u64,
}

impl PpEnv<'_> {
    /// Counter RNG for `(mechanism stream, round)`: a pure function of
    /// the run's noise key, so any round's stream can be re-derived at
    /// any later time (the banded-MF regeneration contract).
    pub fn ctr(&self, stream: u64, round: u64) -> CtrRng {
        CtrRng::new(round_key(self.noise_key, round), stream)
    }
}

/// Clip a statistic value to an L2 bound through the side's clip kernel.
/// Dense values run through `env.clip` (the L1 Pallas artifact on
/// workers); sparse values are clipped on their nonzeros via
/// [`ops::l2_clip`], which is exact for the L2 norm (absent coordinates
/// are zero) and avoids padding a sparse update to the kernel's fixed
/// input shape. Returns the pre-clip norm.
pub(crate) fn clip_value(env: &mut PpEnv, v: &mut StatValue, bound: f32) -> Result<f64> {
    match v {
        StatValue::Dense(d) => env.clip.clip(d, bound),
        StatValue::Sparse { val, .. } => Ok(ops::l2_clip(val, bound)),
        // Wire quantization runs *after* DP clipping (the quantizer is the
        // last local step), so a quantized value reaching the clip is a
        // config-ordering surprise rather than a hot path: decode, clip
        // exactly, and leave the value dense.
        StatValue::Quantized { .. } => {
            let d = v.values_mut();
            env.clip.clip(d, bound)
        }
    }
}

pub trait Postprocessor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Transform one user's statistics on the worker (paper Alg. 1 l.14).
    fn postprocess_one_user(
        &self,
        _stats: &mut Statistics,
        _ctx: &CentralContext,
        _env: &mut PpEnv,
    ) -> Result<Metrics> {
        Ok(Metrics::new())
    }

    /// Transform the aggregate on the server (paper Alg. 1 l.18; invoked
    /// in reversed list order by the backend).
    fn postprocess_server(
        &self,
        _stats: &mut Statistics,
        _ctx: &CentralContext,
        _env: &mut PpEnv,
    ) -> Result<Metrics> {
        Ok(Metrics::new())
    }

    /// Participation filter consulted during cohort sampling — the hook
    /// the banded-MF mechanism uses to enforce min-separation (paper App.
    /// C.4). Default: everyone may participate.
    fn may_participate(&self, _uid: usize, _iteration: u64) -> bool {
        true
    }

    /// Notification that `uid` was scheduled at `iteration`.
    fn record_participation(&self, _uid: usize, _iteration: u64) {}
}

/// Weight a user's contribution by its number of datapoints (classic
/// FedAvg weighting). Scales every vector by w and sets the aggregation
/// weight, so the server-side average is the datapoint-weighted mean.
/// DP presets omit this: equal weighting keeps per-user sensitivity
/// uniform (DP-FedAvg).
pub struct WeightByDatapoints {
    /// Cap on the weight (paper-style "max participation weight"; 0 = no
    /// cap). Bounds one user's influence even without DP.
    pub cap: f64,
}

impl Postprocessor for WeightByDatapoints {
    fn name(&self) -> &'static str {
        "weight-by-datapoints"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut w = env.user_len as f64;
        if self.cap > 0.0 {
            w = w.min(self.cap);
        }
        // statistics arrive with weight 1; rescale values and weight
        let scale = (w / stats.weight.max(1e-12)) as f32;
        for v in stats.vecs.values_mut() {
            v.scale(scale);
        }
        stats.weight = w;
        Ok(Metrics::new())
    }
}

/// Clip each user's update to an L2 bound through the side's clip kernel
/// (L1 Pallas artifact on workers). This is the sensitivity-control half
/// of central DP; the noise half lives in `privacy::*` mechanisms, which
/// *contain* a `NormClip` so bound and noise scale can never diverge
/// (paper §3: "tight integration ... to prevent errors").
pub struct NormClip {
    pub bound: f32,
}

impl Postprocessor for NormClip {
    fn name(&self) -> &'static str {
        "norm-clip"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.vecs.get_mut(super::stats::UPDATE) {
            let norm = clip_value(env, update, self.bound)?;
            m.add_central("clip/pre-norm", norm, 1.0);
            m.add_central("clip/clipped-frac", (norm > self.bound as f64) as u8 as f64, 1.0);
        }
        Ok(m)
    }
}

/// Keep only the top-k largest-magnitude coordinates of the update
/// (sparsification for communication research). The zeroed mass is
/// reported so experiments can trade sparsity against accuracy. With
/// `emit_sparse` the surviving coordinates are re-encoded as a sparse
/// [`StatValue`], so the compact form travels through aggregation and
/// the wire-cost metrics end-to-end.
pub struct TopKSparsifier {
    pub k: usize,
    /// Re-encode the sparsified update as `StatValue::Sparse` when that
    /// is smaller than the dense form.
    pub emit_sparse: bool,
}

impl Postprocessor for TopKSparsifier {
    fn name(&self) -> &'static str {
        "top-k"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        _env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(value) = stats.vecs.get_mut(super::stats::UPDATE) {
            let update = value.values_mut();
            if self.k < update.len() {
                let mut idx: Vec<usize> = (0..update.len()).collect();
                idx.select_nth_unstable_by(self.k, |&a, &b| {
                    update[b].abs().partial_cmp(&update[a].abs()).unwrap()
                });
                let mut dropped = 0f64;
                for &i in &idx[self.k..] {
                    dropped += (update[i] as f64).powi(2);
                    update[i] = 0.0;
                }
                m.add_central("topk/dropped-l2", dropped.sqrt(), 1.0);
            }
            m.add_central("topk/kept", self.k.min(update.len()) as f64, 1.0);
            if self.emit_sparse {
                let taken = std::mem::take(value);
                *value = taken.compact();
            }
        }
        Ok(m)
    }
}

/// Uniform scalar quantization to `bits` bits over the update's dynamic
/// range (compression emulation: quantize-dequantize, so downstream code
/// sees the lossy values a real wire format would deliver).
pub struct UniformQuantizer {
    pub bits: u32,
}

impl Postprocessor for UniformQuantizer {
    fn name(&self) -> &'static str {
        "quantize"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        _env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(value) = stats.vecs.get_mut(super::stats::UPDATE) {
            let update = value.values_mut();
            let levels = (1u64 << self.bits.clamp(1, 24)) as f32 - 1.0;
            let max = update.iter().fold(0f32, |a, &x| a.max(x.abs()));
            if max > 0.0 {
                let step = 2.0 * max / levels;
                let mut err = 0f64;
                for v in update.iter_mut() {
                    let q = ((*v + max) / step).round() * step - max;
                    err += ((*v - q) as f64).powi(2);
                    *v = q;
                }
                m.add_central("quant/mse", err, update.len() as f64);
            }
            m.add_central(
                "quant/bits-per-coord",
                self.bits as f64,
                1.0,
            );
        }
        Ok(m)
    }
}

/// Encode the update in a compact wire format ([`StatValue::Quantized`]:
/// int8-with-scale or IEEE binary16) as the *last* local step, so the
/// narrow codes — not f32s — are what ships to the aggregator, where they
/// decode on arrival (`--quantize {f16,int8}`). Unlike
/// [`UniformQuantizer`] (a lossy-emulation study knob) this changes the
/// actual wire representation and byte accounting (`sys/user-update-bytes`).
///
/// With `error_feedback` the per-user quantization residual
/// `e_t = (x_t + e_{t-1}) - Q(x_t + e_{t-1})` is carried to the next
/// round and folded back in before encoding, driving the *mean* round
/// -trip bias to ~0 over repeated rounds even though each round is lossy.
/// Residuals are keyed by uid — not worker — so the feedback follows a
/// user across dispatch placements; the map lives behind a mutex because
/// all workers share one postprocessor chain.
///
/// Runs after DP: the noise mechanism adds calibrated noise to exact
/// f32s and the *noised* update is what gets encoded, so the DP guarantee
/// is unchanged while the wire narrows (documented approximation:
/// DESIGN.md §3).
pub struct WireQuantizer {
    /// Code width: 8 = symmetric int8 fixed point with per-update scale,
    /// 16 = IEEE binary16.
    pub bits: u8,
    /// Carry per-user residuals across rounds (on by default from config).
    pub error_feedback: bool,
    residuals: Mutex<HashMap<usize, Vec<f32>>>,
}

impl WireQuantizer {
    pub fn new(bits: u8, error_feedback: bool) -> Self {
        WireQuantizer { bits, error_feedback, residuals: Mutex::new(HashMap::new()) }
    }
}

impl Postprocessor for WireQuantizer {
    fn name(&self) -> &'static str {
        "wire-quantize"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        let Some(value) = stats.vecs.get_mut(super::stats::UPDATE) else {
            return Ok(m);
        };
        if matches!(value, StatValue::Quantized { .. }) || value.is_empty() {
            return Ok(m);
        }
        let dim = value.len();

        // Fold the carried residual back in before encoding (e_{t-1}).
        if self.error_feedback {
            let mut guard = self.residuals.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(res) = guard.get(&env.uid) {
                match value {
                    StatValue::Dense(d) => {
                        let n = d.len().min(res.len());
                        ops::add_assign(&mut d[..n], &res[..n]);
                    }
                    StatValue::Sparse { idx, val, .. } => {
                        for (i, v) in idx.iter().zip(val.iter_mut()) {
                            if let Some(r) = res.get(*i as usize) {
                                *v += *r;
                            }
                        }
                    }
                    StatValue::Quantized { .. } => unreachable!("early-returned above"),
                }
            }
        }

        let q = value.quantize(self.bits);

        // Decode the codes once: the per-coordinate decode error is both
        // the quant/err-l2 metric and the next round's residual.
        let mut dec: Vec<f32> = Vec::new();
        if let StatValue::Quantized { scale, bits, data, .. } = &q {
            match *bits {
                8 => ops::dequantize_i8(data, *scale, &mut dec),
                _ => ops::dequantize_f16(data, &mut dec),
            }
        }
        let mut err_sq = 0f64;
        if self.error_feedback {
            let mut guard = self.residuals.lock().unwrap_or_else(|p| p.into_inner());
            let res = guard.entry(env.uid).or_default();
            if res.len() < dim {
                res.resize(dim, 0.0);
            }
            match &*value {
                StatValue::Dense(d) => {
                    for j in 0..d.len() {
                        let e = d[j] - dec[j];
                        res[j] = e;
                        err_sq += (e as f64).powi(2);
                    }
                }
                StatValue::Sparse { idx, val, .. } => {
                    for (k, &i) in idx.iter().enumerate() {
                        let e = val[k] - dec[k];
                        res[i as usize] = e;
                        err_sq += (e as f64).powi(2);
                    }
                }
                StatValue::Quantized { .. } => unreachable!("early-returned above"),
            }
        } else {
            match &*value {
                StatValue::Dense(d) => {
                    for j in 0..d.len() {
                        err_sq += ((d[j] - dec[j]) as f64).powi(2);
                    }
                }
                StatValue::Sparse { val, .. } => {
                    for (k, v) in val.iter().enumerate() {
                        err_sq += ((*v - dec[k]) as f64).powi(2);
                    }
                }
                StatValue::Quantized { .. } => unreachable!("early-returned above"),
            }
        }

        m.add_central("quant/err-l2", err_sq.sqrt(), 1.0);
        m.add_central("quant/wire-bytes", q.wire_bytes() as f64, 1.0);
        *value = q;
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::context::LocalParams;
    use crate::fl::model::RustClip;

    fn ctx() -> CentralContext {
        CentralContext::train(0, 4, LocalParams::default(), 1)
    }

    fn env(rng: &mut Rng, user_len: usize) -> PpEnv<'_> {
        // rng borrowed; clip is the pure-Rust oracle
        PpEnv {
            clip: &RustClip,
            rng,
            user_len,
            uid: 0,
            noise_key: 0,
            noise_threads: 0,
            noise_nanos: 0,
        }
    }

    #[test]
    fn weighting_scales_vectors_and_weight() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![1.0, 2.0], 1.0);
        let pp = WeightByDatapoints { cap: 0.0 };
        pp.postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 5)).unwrap();
        assert_eq!(s.weight, 5.0);
        assert_eq!(s.update(), &[5.0, 10.0]);
        // the weighted average recovers the original value
        s.average_in_place();
        assert_eq!(s.update(), &[1.0, 2.0]);
    }

    #[test]
    fn weighting_cap_applies() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![1.0], 1.0);
        let pp = WeightByDatapoints { cap: 3.0 };
        pp.postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 100)).unwrap();
        assert_eq!(s.weight, 3.0);
    }

    #[test]
    fn norm_clip_bounds_sensitivity() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![3.0, 4.0], 1.0);
        let pp = NormClip { bound: 1.0 };
        let m = pp.postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1)).unwrap();
        assert!((crate::util::l2_norm(s.update()) - 1.0).abs() < 1e-6);
        assert!((m.get("clip/pre-norm").unwrap() - 5.0).abs() < 1e-6);
        assert_eq!(m.get("clip/clipped-frac").unwrap(), 1.0);
    }

    #[test]
    fn topk_keeps_largest() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![0.1, -5.0, 3.0, 0.2], 1.0);
        TopKSparsifier { k: 2, emit_sparse: false }
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        assert_eq!(s.update(), &[0.0, -5.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_noop_when_k_ge_len() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![1.0, 2.0], 1.0);
        TopKSparsifier { k: 10, emit_sparse: false }
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        assert_eq!(s.update(), &[1.0, 2.0]);
    }

    #[test]
    fn topk_emit_sparse_ships_compact_update() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![0.1, -5.0, 3.0, 0.2, 0.0, 0.0, 0.0, 0.0], 1.0);
        TopKSparsifier { k: 2, emit_sparse: true }
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        let v = s.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }), "expected sparse, got {v:?}");
        assert_eq!(s.element_count(), 2);
        assert_eq!(v.to_dense_vec(), vec![0.0, -5.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        // and the sparse update still clips exactly
        let m = NormClip { bound: 1.0 }
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        assert!((m.get("clip/pre-norm").unwrap() - (34.0f64).sqrt()).abs() < 1e-5);
        assert!((s.update_value().unwrap().l2_norm() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn wire_quantizer_int8_ships_4x_fewer_bytes() {
        let mut rng = Rng::seed_from_u64(7);
        let d = 1000usize;
        let update: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
        let f32_bytes = StatValue::Dense(update.clone()).wire_bytes();
        let mut s = Statistics::new_update(update, 1.0);
        let m = WireQuantizer::new(8, true)
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        let v = s.update_value().unwrap();
        assert!(matches!(v, StatValue::Quantized { bits: 8, .. }), "got {v:?}");
        let ratio = f32_bytes as f64 / v.wire_bytes() as f64;
        assert!(ratio >= 3.5, "int8 wire bytes only {ratio:.2}x smaller");
        assert_eq!(m.get("quant/wire-bytes").unwrap(), v.wire_bytes() as f64);
        assert!(m.get("quant/err-l2").unwrap() > 0.0);
    }

    #[test]
    fn wire_quantizer_keeps_sparse_sparse() {
        let mut rng = Rng::seed_from_u64(0);
        let mut s = Statistics::new_update(vec![0.0; 8], 1.0);
        *s.vecs.get_mut(crate::fl::stats::UPDATE).unwrap() =
            StatValue::Sparse { dim: 8, idx: vec![1, 5], val: vec![0.5, -0.25] };
        WireQuantizer::new(16, true)
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        let v = s.update_value().unwrap();
        assert!(matches!(v, StatValue::Quantized { idx: Some(_), bits: 16, .. }), "got {v:?}");
        // 0.5 / -0.25 are exact in binary16: the decoded value is identical
        assert_eq!(v.to_dense_vec(), vec![0.0, 0.5, 0.0, 0.0, 0.0, -0.25, 0.0, 0.0]);
    }

    #[test]
    fn wire_quantizer_error_feedback_kills_mean_bias() {
        // the same update quantized for N rounds: without feedback the
        // deterministic rounding error repeats (mean bias = one-round
        // error); with feedback the carried residual bounds the *sum* of
        // errors by one quantization step, so mean bias ~ step / N.
        let mut rng = Rng::seed_from_u64(0);
        let truth = [0.003f32, -0.0071, 0.01, 0.0042];
        let n_rounds = 64;
        let pp = WireQuantizer::new(8, true);
        let mut sum = vec![0f64; truth.len()];
        for _ in 0..n_rounds {
            let mut s = Statistics::new_update(truth.to_vec(), 1.0);
            pp.postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1)).unwrap();
            let dec = s.update_value().unwrap().to_dense_vec();
            for (a, b) in sum.iter_mut().zip(&dec) {
                *a += *b as f64;
            }
        }
        let scale = 0.01f32 / 127.0; // max|truth| / 127
        for (j, t) in truth.iter().enumerate() {
            let bias = (sum[j] / n_rounds as f64 - *t as f64).abs();
            assert!(
                bias <= scale as f64 / n_rounds as f64 + 1e-9,
                "coord {j}: mean bias {bias:e} not killed by error feedback"
            );
        }
    }

    #[test]
    fn clip_decodes_quantized_input() {
        // config-ordering surprise path: a quantized value reaching the
        // clip is decoded and clipped exactly
        let mut rng = Rng::seed_from_u64(0);
        let mut v = StatValue::Dense(vec![3.0, 4.0]).quantize(16);
        let norm = clip_value(&mut env(&mut rng, 1), &mut v, 1.0).unwrap();
        assert!((norm - 5.0).abs() < 1e-6);
        assert!(matches!(v, StatValue::Dense(_)));
        assert!((v.l2_norm() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn quantizer_bounded_error() {
        let mut rng = Rng::seed_from_u64(0);
        let orig = vec![0.5f32, -0.25, 0.125, 1.0];
        let mut s = Statistics::new_update(orig.clone(), 1.0);
        UniformQuantizer { bits: 8 }
            .postprocess_one_user(&mut s, &ctx(), &mut env(&mut rng, 1))
            .unwrap();
        let step = 2.0 * 1.0 / 255.0;
        for (a, b) in s.update().iter().zip(&orig) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6);
        }
    }
}
