//! A pure-Rust linear-regression [`Model`]: the smallest gradient-descent
//! member of the model zoo. It implements the exact unified local step of
//! the L2 artifacts — g = ∇L + µ(θ′−θ) + c_diff — so every algorithm
//! (FedAvg/FedProx/SCAFFOLD) exercises identical semantics without a PJRT
//! round-trip. Used by integration tests, docs and as a template for
//! custom non-NN models (paper App. B.1: "the Model class can be extended
//! to implement non-neural-network models").

use anyhow::{bail, Result};

use super::context::LocalParams;
use super::metrics::Metrics;
use super::model::{Model, ScoreSink, TrainOutput};
use crate::data::UserData;
use crate::util::rng::Rng;

/// Linear regression on [`UserData::Tabular`]: params = [w (dim), b].
pub struct LinearModel {
    pub dim: usize,
    central: Vec<f32>,
    work: Vec<f32>,
}

impl LinearModel {
    pub fn new(dim: usize) -> Self {
        LinearModel { dim, central: vec![0.0; dim + 1], work: vec![0.0; dim + 1] }
    }

    pub fn param_len(dim: usize) -> usize {
        dim + 1
    }

    fn predict(params: &[f32], row: &[f32]) -> f32 {
        let dim = params.len() - 1;
        let mut y = params[dim];
        for (w, x) in params[..dim].iter().zip(row) {
            y += w * x;
        }
        y
    }
}

impl Model for LinearModel {
    fn param_count(&self) -> usize {
        self.central.len()
    }

    fn set_central(&mut self, central: &[f32]) {
        self.central.copy_from_slice(central);
    }

    fn central(&self) -> &[f32] {
        &self.central
    }

    fn train_local(
        &mut self,
        data: &UserData,
        p: &LocalParams,
        c_diff: Option<&[f32]>,
        seed: u64,
    ) -> Result<TrainOutput> {
        let (x, y, dim) = match data {
            UserData::Tabular { x, y, dim } if *dim == self.dim => (x, y, *dim),
            UserData::Tabular { dim, .. } => bail!("dim mismatch: {} vs {}", dim, self.dim),
            _ => bail!("LinearModel wants Tabular data"),
        };
        let n = y.len();
        if n == 0 {
            return Ok(TrainOutput::default());
        }
        self.work.copy_from_slice(&self.central);
        let mut rng = Rng::seed_from_u64(seed);
        let mut order: Vec<usize> = (0..n).collect();
        let mut out = TrainOutput::default();
        let bs = p.batch_size.max(1);

        for _ in 0..p.epochs.max(1) {
            rng.shuffle(&mut order);
            for chunk in order.chunks(bs) {
                if p.max_steps > 0 && out.steps >= p.max_steps {
                    break;
                }
                // batch gradient of 0.5*(pred-y)^2
                let mut grad = vec![0.0f32; dim + 1];
                let mut loss = 0f64;
                for &i in chunk {
                    let row = &x[i * dim..(i + 1) * dim];
                    let err = Self::predict(&self.work, row) - y[i];
                    loss += 0.5 * (err as f64) * (err as f64);
                    for d in 0..dim {
                        grad[d] += err * row[d];
                    }
                    grad[dim] += err;
                }
                let inv = 1.0 / chunk.len() as f32;
                for g in grad.iter_mut() {
                    *g *= inv;
                }
                // unified step: g += mu*(theta' - theta) + c_diff
                for d in 0..=dim {
                    let mut g = grad[d] + p.mu * (self.work[d] - self.central[d]);
                    if let Some(c) = c_diff {
                        g += c[d];
                    }
                    self.work[d] -= p.lr * g;
                }
                out.loss_sum += loss;
                out.wsum += chunk.len() as f64;
                out.steps += 1;
            }
        }
        let mut delta = vec![0.0f32; dim + 1];
        for d in 0..=dim {
            delta[d] = self.central[d] - self.work[d];
        }
        out.update = delta;
        Ok(out)
    }

    fn evaluate(&mut self, data: &UserData, _sink: Option<&mut ScoreSink>) -> Result<Metrics> {
        let (x, y, dim) = match data {
            UserData::Tabular { x, y, dim } if *dim == self.dim => (x, y, *dim),
            _ => bail!("LinearModel wants Tabular data of dim {}", self.dim),
        };
        let mut loss = 0f64;
        for (i, &target) in y.iter().enumerate() {
            let err = Self::predict(&self.central, &x[i * dim..(i + 1) * dim]) - target;
            loss += 0.5 * (err as f64) * (err as f64);
        }
        let mut m = Metrics::new();
        m.add_central("loss", loss, y.len() as f64);
        Ok(m)
    }

    fn name(&self) -> &str {
        "linear"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn user(n: usize, dim: usize, seed: u64) -> UserData {
        // y = 2*x0 - x1 + 0.5
        let mut rng = Rng::seed_from_u64(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            y.push(2.0 * row[0] - row[1] + 0.5);
            x.extend(row);
        }
        UserData::Tabular { x, y, dim }
    }

    #[test]
    fn local_sgd_reduces_loss() {
        let mut m = LinearModel::new(3);
        let data = user(64, 3, 0);
        let p = LocalParams { epochs: 20, batch_size: 8, lr: 0.1, mu: 0.0, max_steps: 0 };
        let before = m.evaluate(&data, None).unwrap().get("loss").unwrap();
        let out = m.train_local(&data, &p, None, 1).unwrap();
        // apply the delta as FedAvg would with lr 1
        let new: Vec<f32> = m.central().iter().zip(&out.update).map(|(c, d)| c - d).collect();
        m.set_central(&new);
        let after = m.evaluate(&data, None).unwrap().get("loss").unwrap();
        assert!(after < before * 0.2, "{before} -> {after}");
    }

    #[test]
    fn prox_term_shrinks_delta() {
        let data = user(64, 3, 0);
        let p0 = LocalParams { epochs: 5, batch_size: 8, lr: 0.1, mu: 0.0, max_steps: 0 };
        let p_mu = LocalParams { mu: 10.0, ..p0.clone() };
        let mut m = LinearModel::new(3);
        let d0 = m.train_local(&data, &p0, None, 1).unwrap();
        let dmu = m.train_local(&data, &p_mu, None, 1).unwrap();
        assert!(
            crate::util::l2_norm(&dmu.update) < crate::util::l2_norm(&d0.update),
            "prox did not shrink the update"
        );
    }

    #[test]
    fn c_diff_shifts_update() {
        let data = user(32, 2, 0);
        let p = LocalParams { epochs: 1, batch_size: 32, lr: 0.1, mu: 0.0, max_steps: 0 };
        let mut m = LinearModel::new(2);
        let base = m.train_local(&data, &p, None, 5).unwrap();
        let c = vec![1.0f32; 3];
        let shifted = m.train_local(&data, &p, Some(&c), 5).unwrap();
        // one step of extra gradient c with lr 0.1 adds +0.1*c to delta
        for (b, s) in base.update.iter().zip(&shifted.update) {
            assert!((s - b - 0.1).abs() < 1e-5, "{s} vs {b}");
        }
    }

    #[test]
    fn max_steps_caps_work() {
        let data = user(100, 2, 0);
        let p = LocalParams { epochs: 10, batch_size: 10, lr: 0.01, mu: 0.0, max_steps: 3 };
        let mut m = LinearModel::new(2);
        let out = m.train_local(&data, &p, None, 0).unwrap();
        assert_eq!(out.steps, 3);
    }
}
