//! Training statistics: what a user contributes for aggregation.
//!
//! The common case is a single weighted model-update vector ("update");
//! SCAFFOLD adds a second vector ("c_delta"). Keeping named values keeps
//! the aggregator, postprocessors and DP mechanisms algorithm-agnostic,
//! matching the paper's separation of concerns (App. B.2).
//!
//! Each named value is a [`StatValue`] — dense, sparse with sorted
//! indices, or quantized on the wire (f16 / int8-with-scale, decoded on
//! arrival at any accumulator) — so LoRA-/GBDT-style scenarios ship
//! compact updates through the same aggregation and privacy machinery
//! (see `crate::tensor`).

use std::collections::BTreeMap;

pub use crate::tensor::StatValue;

/// Canonical key of the model-update vector.
pub const UPDATE: &str = "update";
/// SCAFFOLD's control-variate delta.
pub const C_DELTA: &str = "c_delta";

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Statistics {
    /// Aggregation weight (typically Σ user weights; used for averaging).
    pub weight: f64,
    pub vecs: BTreeMap<String, StatValue>,
}

impl Statistics {
    pub fn new_update(update: Vec<f32>, weight: f64) -> Self {
        Self::new_update_value(StatValue::Dense(update), weight)
    }

    pub fn new_update_value(update: StatValue, weight: f64) -> Self {
        let mut vecs = BTreeMap::new();
        vecs.insert(UPDATE.to_string(), update);
        Statistics { weight, vecs }
    }

    /// Dense view of the update vector; empty when missing or sparse
    /// (use [`Self::update_value`] or densify first for sparse access).
    pub fn update(&self) -> &[f32] {
        self.vecs.get(UPDATE).and_then(|v| v.as_dense()).unwrap_or(&[])
    }

    pub fn update_value(&self) -> Option<&StatValue> {
        self.vecs.get(UPDATE)
    }

    /// Wire elements across all named values — what `sys/user-update-elems`
    /// counts. Width-independent: a quantized value reports the same
    /// element count as the f32 it encodes.
    pub fn wire_elements(&self) -> usize {
        self.vecs.values().map(|v| v.wire_elements()).sum()
    }

    /// Serialized payload bytes across all named values — what
    /// `Counters::stat_bytes` / `sys/user-update-bytes` count. Unlike
    /// [`Self::wire_elements`] this reflects the stored width, so it is
    /// where [`StatValue::Quantized`] shows its shrink.
    pub fn wire_bytes(&self) -> usize {
        self.vecs.values().map(|v| v.wire_bytes()).sum()
    }

    /// Entry-style mutable access to the dense update buffer: inserts an
    /// empty vector when the key is missing and densifies a sparse
    /// update in place, so it never panics.
    pub fn update_mut(&mut self) -> &mut Vec<f32> {
        self.entry_dense(UPDATE)
    }

    /// Entry-style mutable access to any key's dense buffer (inserting
    /// an empty dense vector when missing).
    pub fn entry_dense(&mut self, key: &str) -> &mut Vec<f32> {
        if !self.vecs.contains_key(key) {
            self.vecs.insert(key.to_string(), StatValue::Dense(Vec::new()));
        }
        self.vecs.get_mut(key).expect("just inserted").densify()
    }

    /// Mutable dense buffer for `key`, densifying a sparse value in
    /// place; `None` when the key is absent. Mechanisms that must touch
    /// every coordinate (additive noise) use this.
    pub fn dense_mut(&mut self, key: &str) -> Option<&mut Vec<f32>> {
        self.vecs.get_mut(key).map(|v| v.densify())
    }

    pub fn insert(&mut self, key: &str, v: Vec<f32>) {
        self.vecs.insert(key.to_string(), StatValue::Dense(v));
    }

    pub fn insert_value(&mut self, key: &str, v: StatValue) {
        self.vecs.insert(key.to_string(), v);
    }

    /// Dense slice for `key`; `None` when absent or sparse.
    pub fn get(&self, key: &str) -> Option<&[f32]> {
        self.vecs.get(key).and_then(|v| v.as_dense())
    }

    pub fn value(&self, key: &str) -> Option<&StatValue> {
        self.vecs.get(key)
    }

    /// Total number of stored f32 elements across values (communication
    /// cost; nonzeros only for sparse values).
    pub fn element_count(&self) -> usize {
        self.vecs.values().map(|v| v.element_count()).sum()
    }

    /// Convert every value to its dense form in place (no-op when all
    /// are already dense). Algorithms call this before consuming the
    /// aggregate through dense slices.
    pub fn densify_all(&mut self) {
        for v in self.vecs.values_mut() {
            v.densify();
        }
    }

    /// Divide all values by the accumulated weight -> weighted average.
    pub fn average_in_place(&mut self) {
        if self.weight > 0.0 {
            let inv = (1.0 / self.weight) as f32;
            for v in self.vecs.values_mut() {
                v.scale(inv);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_roundtrip_and_average() {
        let mut s = Statistics::new_update(vec![2.0, 4.0], 2.0);
        s.insert(C_DELTA, vec![1.0, 1.0]);
        assert_eq!(s.update(), &[2.0, 4.0]);
        assert_eq!(s.element_count(), 4);
        s.average_in_place();
        assert_eq!(s.update(), &[1.0, 2.0]);
        assert_eq!(s.get(C_DELTA).unwrap(), &[0.5, 0.5]);
    }

    #[test]
    fn zero_weight_average_is_noop() {
        let mut s = Statistics::new_update(vec![3.0], 0.0);
        s.average_in_place();
        assert_eq!(s.update(), &[3.0]);
    }

    #[test]
    fn update_mut_inserts_missing_key() {
        // regression: used to panic with "no update vector"
        let mut s = Statistics::default();
        assert!(s.update().is_empty());
        s.update_mut().extend_from_slice(&[1.0, 2.0]);
        assert_eq!(s.update(), &[1.0, 2.0]);
        // and keeps working as plain mutable access afterwards
        s.update_mut()[0] = 5.0;
        assert_eq!(s.update(), &[5.0, 2.0]);
    }

    #[test]
    fn update_mut_densifies_sparse() {
        let mut s = Statistics::new_update_value(
            StatValue::sparse(4, vec![1, 3], vec![2.0, 4.0]),
            1.0,
        );
        assert!(s.update().is_empty()); // dense view of a sparse value
        assert_eq!(s.update_mut().as_slice(), &[0.0, 2.0, 0.0, 4.0]);
        assert_eq!(s.update(), &[0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparse_update_average_and_count() {
        let mut s = Statistics::new_update_value(
            StatValue::sparse(100, vec![7, 42], vec![2.0, 8.0]),
            2.0,
        );
        assert_eq!(s.element_count(), 2);
        s.average_in_place();
        let v = s.update_value().unwrap().to_dense_vec();
        assert_eq!(v[7], 1.0);
        assert_eq!(v[42], 4.0);
    }
}
