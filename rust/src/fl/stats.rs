//! Training statistics: what a user contributes for aggregation.
//!
//! The common case is a single weighted model-update vector ("update");
//! SCAFFOLD adds a second vector ("c_delta"). Keeping named vectors keeps
//! the aggregator, postprocessors and DP mechanisms algorithm-agnostic,
//! matching the paper's separation of concerns (App. B.2).

use std::collections::BTreeMap;

/// Canonical key of the model-update vector.
pub const UPDATE: &str = "update";
/// SCAFFOLD's control-variate delta.
pub const C_DELTA: &str = "c_delta";

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Statistics {
    /// Aggregation weight (typically Σ user weights; used for averaging).
    pub weight: f64,
    pub vecs: BTreeMap<String, Vec<f32>>,
}

impl Statistics {
    pub fn new_update(update: Vec<f32>, weight: f64) -> Self {
        let mut vecs = BTreeMap::new();
        vecs.insert(UPDATE.to_string(), update);
        Statistics { weight, vecs }
    }

    pub fn update(&self) -> &[f32] {
        self.vecs.get(UPDATE).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn update_mut(&mut self) -> &mut Vec<f32> {
        self.vecs.get_mut(UPDATE).expect("no update vector")
    }

    pub fn insert(&mut self, key: &str, v: Vec<f32>) {
        self.vecs.insert(key.to_string(), v);
    }

    pub fn get(&self, key: &str) -> Option<&[f32]> {
        self.vecs.get(key).map(|v| v.as_slice())
    }

    /// Total number of f32 elements across vectors (communication cost).
    pub fn element_count(&self) -> usize {
        self.vecs.values().map(|v| v.len()).sum()
    }

    /// Divide all vectors by the accumulated weight -> weighted average.
    pub fn average_in_place(&mut self) {
        if self.weight > 0.0 {
            let inv = (1.0 / self.weight) as f32;
            for v in self.vecs.values_mut() {
                crate::util::scale(v, inv);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_roundtrip_and_average() {
        let mut s = Statistics::new_update(vec![2.0, 4.0], 2.0);
        s.insert(C_DELTA, vec![1.0, 1.0]);
        assert_eq!(s.update(), &[2.0, 4.0]);
        assert_eq!(s.element_count(), 4);
        s.average_in_place();
        assert_eq!(s.update(), &[1.0, 2.0]);
        assert_eq!(s.get(C_DELTA).unwrap(), &[0.5, 0.5]);
    }

    #[test]
    fn zero_weight_average_is_noop() {
        let mut s = Statistics::new_update(vec![3.0], 0.0);
        s.average_in_place();
        assert_eq!(s.update(), &[3.0]);
    }
}
