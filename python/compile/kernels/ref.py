"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here. `python/tests/test_kernels.py` sweeps shapes and dtypes
with hypothesis and asserts allclose between kernel and oracle.
"""

import jax.numpy as jnp


def clip_scale_ref(v, bound):
    """L2-clip a flat vector to `bound`.

    Returns (clipped, norm). If ||v|| <= bound the vector is returned
    unchanged; otherwise it is scaled by bound/||v||. This is the per-user
    DP sensitivity-control step (paper App. A, Gaussian mechanism step 1).
    """
    norm = jnp.sqrt(jnp.sum(v.astype(jnp.float32) ** 2))
    scale = jnp.minimum(1.0, bound / jnp.maximum(norm, 1e-30))
    return (v * scale).astype(v.dtype), norm


def matmul_ref(x, w):
    """Plain matmul oracle for the tiled Pallas matmul."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def fused_linear_ref(x, w, b, act="id"):
    """matmul + bias + activation oracle for the fused Pallas kernel."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b.astype(
        jnp.float32
    )
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        # tanh-approx gelu, matching the kernel
        y = (
            0.5
            * y
            * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))
        )
    elif act != "id":
        raise ValueError(f"unknown act {act!r}")
    return y.astype(x.dtype)
