"""Pallas kernel: tiled matmul + bias + activation, with a custom VJP.

The dense layers of every benchmark model (CNN head, transformer FF and
logit projection, FLAIR MLP trunk) run through this kernel, so it sits on
the local-training hot path — the bulk of per-user FLOPs in the paper's
simulations.

TPU mapping (DESIGN.md §Hardware-Adaptation): a 3-D grid over
(M/bm, N/bn, K/bk) tiles with MXU-shaped (128,128) output tiles and an
accumulate-in-place inner loop over K — the BlockSpec expresses the
HBM->VMEM schedule that a CUDA kernel would express with threadblocks and
shared-memory staging. Bias-add and activation are fused into the final
K-step so the pre-activation tile never round-trips to HBM.

Autodiff: `pallas_call` has no automatic VJP, so `fused_linear` carries a
`jax.custom_vjp` whose backward pass reuses the same tiled kernel for the
two transposed matmuls (dx = g @ W^T, dW = x^T @ g). This keeps *both*
forward and backward on the L1 kernel.

interpret=True for CPU-PJRT execution (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles.
BM, BN, BK = 128, 128, 128


def _gelu(y):
    return 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))


_ACTS = {
    "id": lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0.0),
    "gelu": _gelu,
}


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk, act):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = _ACTS[act](o_ref[...] + b_ref[...])


def _pad2(a, m, n):
    pm, pn = (-a.shape[0]) % m, (-a.shape[1]) % n
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def _matmul_bias_act(x, w, b, act="id", bm=BM, bn=BN, bk=BK):
    """Tiled pallas (x @ w + b) then activation. Pads to tile multiples."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    bp = jnp.pad(b, (0, (-n) % bn)).reshape(1, -1)
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=gk, act=act),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n].astype(x.dtype)


def _matmul_raw(x, w):
    return _matmul_bias_act(x, w, jnp.zeros((w.shape[1],), x.dtype), act="id")


@jax.custom_vjp
def matmul(x, w):
    """Plain tiled pallas matmul (no bias, no activation), differentiable."""
    return _matmul_raw(x, w)


def _mm_fwd(x, w):
    return _matmul_raw(x, w), (x, w)


def _mm_bwd(res, dy):
    x, w = res
    return _matmul_raw(dy, w.T), _matmul_raw(x.T, dy)


matmul.defvjp(_mm_fwd, _mm_bwd)


def _act_grad(act, pre):
    if act == "id":
        return jnp.ones_like(pre)
    if act == "relu":
        return (pre > 0).astype(pre.dtype)
    if act == "gelu":
        # d/dy of tanh-approx gelu
        c = 0.7978845608028654
        t = jnp.tanh(c * (pre + 0.044715 * pre**3))
        return 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t**2) * c * (
            1.0 + 3 * 0.044715 * pre**2
        )
    raise ValueError(act)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act="id"):
    """act(x @ w + b), forward and backward both on the Pallas kernel."""
    return _matmul_bias_act(x, w, b, act=act)


def _fl_fwd(x, w, b, act):
    pre = _matmul_bias_act(x, w, b, act="id")
    return _ACTS[act](pre), (x, w, pre)


def _fl_bwd(act, res, dy):
    x, w, pre = res
    g = dy * _act_grad(act, pre)
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fl_fwd, _fl_bwd)
