"""Pallas kernel: fused L2-norm + clip of a flat model update.

This is the per-user DP clipping step that pfl-research keeps on the GPU
end-to-end (paper §3 item 4 and §A: "model updates from each user are
clipped so that their L2 norm is upper-bounded"). It is the L1 hot-spot of
the privacy path: every sampled user's update passes through it once per
central iteration.

TPU mapping (DESIGN.md §Hardware-Adaptation): the vector is processed in
row blocks of BLOCK elements; each block is one HBM->VMEM transfer
(BLOCK * 4 bytes = 512 KiB at the default, far below the ~16 MiB VMEM
budget), reduced on the VPU. Two passes over HBM (reduce, then scale) —
arithmetic intensity is O(1) so the kernel is bandwidth-bound and two
passes is the roofline for a clip that needs the *global* norm before it
can scale. interpret=True for CPU-PJRT execution (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128 * 1024 f32 = 512 KiB per block in VMEM.
BLOCK = 128 * 1024


def _sumsq_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[0] = jnp.sum(x * x)


def _scale_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0]


def _pad_to_block(v, block):
    n = v.shape[0]
    rem = (-n) % block
    if rem:
        v = jnp.concatenate([v, jnp.zeros((rem,), v.dtype)])
    return v


@functools.partial(jax.jit, static_argnames=("block",))
def clip_scale(v, bound, block=BLOCK):
    """L2-clip flat vector `v` to `bound`; returns (clipped, norm).

    Zero-padding to a block multiple does not change the norm, and the
    padded tail is dropped before returning.
    """
    n = v.shape[0]
    vp = _pad_to_block(v, block)
    nb = vp.shape[0] // block

    partial_sums = pl.pallas_call(
        _sumsq_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.float32),
        interpret=True,
    )(vp)

    norm = jnp.sqrt(jnp.sum(partial_sums))
    scale = jnp.minimum(1.0, bound / jnp.maximum(norm, 1e-30)).astype(v.dtype)

    scaled = pl.pallas_call(
        _scale_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(vp.shape, v.dtype),
        interpret=True,
    )(vp, scale.reshape(1))

    return scaled[:n], norm
