"""AOT compile path: lower every (model x step) to HLO *text* + manifest.

This is the only place Python touches the stack. `make artifacts` runs it
once; the Rust coordinator then loads `artifacts/*.hlo.txt` through the
PJRT CPU client and Python never appears on the simulation path.

Interchange format is HLO text, NOT `HloModuleProto.serialize()` — jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.clip_scale import clip_scale
from .model import MODELS
from .models import lora_lm
from .models.common import manifest_layout


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def _io_spec(args):
    return [
        {"shape": list(a.shape), "dtype": _DTYPE[a.dtype]} for a in args
    ]


def _out_spec(fn, args):
    outs = jax.eval_shape(fn, *args)
    if not isinstance(outs, tuple):
        outs = (outs,)
    return [{"shape": list(o.shape), "dtype": _DTYPE[o.dtype]} for o in outs]


def _emit(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return text


def build_all(out_dir: str, only=None, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "models": {}, "artifacts": {}}

    for name, mdef in MODELS.items():
        if only and name not in only:
            continue
        specs, train, eval_step, train_args, eval_args = mdef.make_steps(
            mdef.train_batch, mdef.eval_batch
        )
        entries, total = manifest_layout(specs)
        model_entry = {
            "param_count": total,
            "layout": entries,
            "train_batch": mdef.train_batch,
            "eval_batch": mdef.eval_batch,
            "flops_per_train_step": mdef.module.flops_per_train_step(
                mdef.train_batch
            ),
            "description": mdef.description,
        }
        if mdef.has_base:
            bentries, btotal = manifest_layout(lora_lm.base_param_specs())
            model_entry["base_param_count"] = btotal
            model_entry["base_layout"] = bentries

        for step_name, fn, args in (
            ("train", train, train_args(total)),
            ("eval", eval_step, eval_args(total)),
        ):
            art = f"{name}_{step_name}"
            path = os.path.join(out_dir, art + ".hlo.txt")
            if verbose:
                print(f"lowering {art} ...", flush=True)
            text = _emit(fn, args, path)
            manifest["artifacts"][art] = {
                "file": os.path.basename(path),
                "inputs": _io_spec(args),
                "outputs": _out_spec(fn, args),
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }

        # Per-model clip artifact (param-count-shaped): the L1 Pallas
        # clip_scale kernel as a standalone executable for the DP
        # postprocessor in rust.
        clip_args = (
            jax.ShapeDtypeStruct((total,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        art = f"{name}_clip"
        path = os.path.join(out_dir, art + ".hlo.txt")
        if verbose:
            print(f"lowering {art} ...", flush=True)
        text = _emit(lambda v, b: clip_scale(v, b), clip_args, path)
        manifest["artifacts"][art] = {
            "file": os.path.basename(path),
            "inputs": _io_spec(clip_args),
            "outputs": [
                {"shape": [total], "dtype": "f32"},
                {"shape": [], "dtype": "f32"},
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        model_entry["artifacts"] = {
            "train": f"{name}_train",
            "eval": f"{name}_eval",
            "clip": f"{name}_clip",
        }
        manifest["models"][name] = model_entry

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None, help="compat: marker file path")
    p.add_argument("--only", nargs="*", default=None, help="subset of models")
    args = p.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    build_all(out_dir, only=args.only)


if __name__ == "__main__":
    sys.exit(main())
