"""L2 model registry: every benchmark model and its AOT-lowered steps.

Each entry maps a model name to its step builders and batch shapes. The
unified train step (see models/common.py) serves FedAvg, FedProx,
AdaFedProx and SCAFFOLD from a single artifact; SCAFFOLD's control-variate
bookkeeping and FedProx's adaptive mu live in the Rust coordinator.
"""

from dataclasses import dataclass
from typing import Callable

from .models import cnn, lora_lm, mlp_multilabel, transformer


@dataclass
class ModelDef:
    name: str
    module: object
    train_batch: int
    eval_batch: int
    make_steps: Callable
    has_base: bool = False  # lora: frozen base weights are a runtime input
    description: str = ""


MODELS = {
    "cnn_c10": ModelDef(
        name="cnn_c10",
        module=cnn,
        train_batch=10,
        eval_batch=256,
        make_steps=cnn.make_steps,
        description="CIFAR10 benchmark CNN (paper App. C.5)",
    ),
    "lm_so": ModelDef(
        name="lm_so",
        module=transformer,
        train_batch=16,
        eval_batch=64,
        make_steps=transformer.make_steps,
        description="StackOverflow transformer LM, 1.96M params (App. C.6)",
    ),
    "mlp_flair": ModelDef(
        name="mlp_flair",
        module=mlp_multilabel,
        train_batch=16,
        eval_batch=128,
        make_steps=mlp_multilabel.make_steps,
        description="FLAIR multi-label classifier stand-in (App. C.7)",
    ),
    "lora_llm": ModelDef(
        name="lora_llm",
        module=lora_lm,
        train_batch=4,
        eval_batch=8,
        make_steps=lora_lm.make_steps,
        has_base=True,
        description="LLM fine-tune stand-in: frozen base + LoRA r=8 (App. C.8)",
    ),
}
