"""Shared model plumbing: flat-parameter layout, init specs, local step.

All benchmark models expose their parameters to the Rust coordinator as a
single flat f32 vector (pfl-research's "one model per worker, updated
in-place" design maps to one donated flat buffer per worker). The manifest
records the (name, shape, offset, init) layout so Rust can initialize and
inspect tensors without Python.

The *unified local step* lowers FedAvg / FedProx / SCAFFOLD into one HLO
artifact per model: the gradient is

    g = dL/dp + mu * (p - p_global) + c_diff

with mu=0, c_diff=0 recovering plain FedAvg local SGD. One artifact per
model serves every algorithm, exactly mirroring how pfl-research keeps one
resident model and varies only the algorithm objects around it.
"""

import math
from dataclasses import dataclass
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    # init kind: "zeros" | "ones" | "normal" (with std) | "uniform" (+-scale)
    init: str = "normal"
    std: float = 0.02

    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1


def fan_in_std(*fan_in_dims: int, gain: float = 2.0) -> float:
    """He-style init std: sqrt(gain / fan_in)."""
    fan = int(math.prod(fan_in_dims))
    return math.sqrt(gain / max(fan, 1))


def layout(specs: List[ParamSpec]):
    """Return [(spec, offset)] and total size."""
    out, off = [], 0
    for s in specs:
        out.append((s, off))
        off += s.size
    return out, off


def unflatten(flat, specs: List[ParamSpec]):
    """Split a flat vector into the named tensors of `specs`."""
    params, off = {}, 0
    for s in specs:
        params[s.name] = jax.lax.dynamic_slice(flat, (off,), (s.size,)).reshape(
            s.shape
        )
        off += s.size
    return params


def manifest_layout(specs: List[ParamSpec]):
    """JSON-serializable layout for the Rust side."""
    entries, off = [], 0
    for s in specs:
        entries.append(
            {
                "name": s.name,
                "shape": list(s.shape),
                "offset": off,
                "size": s.size,
                "init": s.init,
                "std": s.std,
            }
        )
        off += s.size
    return entries, off


def make_train_step(
    loss_fn: Callable, specs: List[ParamSpec]
) -> Callable:
    """Build the unified local-SGD step for a model.

    loss_fn(params_dict, *batch) -> (mean_loss, (loss_sum, stat_sum, wsum))

    Returns step(flat, global_flat, c_diff, *batch, lr, mu) ->
        (new_flat, loss_sum, stat_sum, wsum)
    """

    def step(flat, global_flat, c_diff, *batch_and_hp):
        *batch, lr, mu = batch_and_hp

        def obj(f):
            params = unflatten(f, specs)
            return loss_fn(params, *batch)

        grads, aux = jax.grad(obj, has_aux=True)(flat)
        loss_sum, stat_sum, wsum = aux
        g = grads + mu * (flat - global_flat) + c_diff
        new_flat = flat - lr * g
        return new_flat, loss_sum, stat_sum, wsum

    return step


def masked_mean(per_example_loss, w):
    """Weighted mean + the sufficient statistics the metrics system wants."""
    loss_sum = jnp.sum(per_example_loss * w)
    wsum = jnp.sum(w)
    return loss_sum / jnp.maximum(wsum, 1e-12), loss_sum, wsum


def softmax_xent(logits, labels, w):
    """Per-example softmax cross entropy with integer labels, masked."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_ex = logz - ll
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    mean, loss_sum, wsum = masked_mean(per_ex, w)
    return mean, loss_sum, jnp.sum(correct * w), wsum


def sigmoid_bce(logits, targets, w):
    """Mean-over-labels BCE per example, masked over the batch."""
    per_label = jnp.maximum(logits, 0) - logits * targets + jnp.log1p(
        jnp.exp(-jnp.abs(logits))
    )
    per_ex = jnp.mean(per_label, axis=-1)
    # "stat" for multi-label: exact-match count is uninformative; use
    # micro-averaged true positives at threshold 0 as the cheap aggregate.
    preds = (logits > 0).astype(jnp.float32)
    tp = jnp.sum(preds * targets, axis=-1)
    mean, loss_sum, wsum = masked_mean(per_ex, w)
    return mean, loss_sum, jnp.sum(tp * w), wsum
