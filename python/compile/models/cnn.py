"""CIFAR10 benchmark CNN (paper App. C.5; architecture after Reddi et al.
"Adaptive Federated Optimization", Table 4 — two conv blocks + two dense).

Dense layers run on the L1 Pallas `fused_linear` kernel; convolutions use
XLA's native conv (the paper's models do the same through torch/tf).
"""

import jax
import jax.numpy as jnp

from ..kernels.fused_linear import fused_linear
from .common import (
    ParamSpec,
    fan_in_std,
    make_train_step,
    softmax_xent,
    unflatten,
)

NUM_CLASSES = 10
IMG = (32, 32, 3)
C1, C2, HID = 32, 64, 128


def param_specs():
    return [
        ParamSpec("conv1_w", (3, 3, 3, C1), "normal", fan_in_std(3, 3, 3)),
        ParamSpec("conv1_b", (C1,), "zeros"),
        ParamSpec("conv2_w", (3, 3, C1, C2), "normal", fan_in_std(3, 3, C1)),
        ParamSpec("conv2_b", (C2,), "zeros"),
        ParamSpec("fc1_w", (8 * 8 * C2, HID), "normal", fan_in_std(8 * 8 * C2)),
        ParamSpec("fc1_b", (HID,), "zeros"),
        ParamSpec("fc2_w", (HID, NUM_CLASSES), "normal", fan_in_std(HID)),
        ParamSpec("fc2_b", (NUM_CLASSES,), "zeros"),
    ]


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(params, x):
    h = jax.nn.relu(_conv(x, params["conv1_w"], params["conv1_b"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv(h, params["conv2_w"], params["conv2_b"]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = fused_linear(h, params["fc1_w"], params["fc1_b"], "relu")
    return fused_linear(h, params["fc2_w"], params["fc2_b"], "id")


def loss_fn(params, x, y, w):
    logits = forward(params, x)
    mean, loss_sum, correct, wsum = softmax_xent(logits, y, w)
    return mean, (loss_sum, correct, wsum)


def make_steps(batch_size: int, eval_batch: int):
    specs = param_specs()
    train = make_train_step(loss_fn, specs)

    def eval_step(flat, x, y, w):
        params = unflatten(flat, specs)
        _, (loss_sum, correct, wsum) = loss_fn(params, x, y, w)
        return loss_sum, correct, wsum

    def train_args(total):
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            f,
            f,
            jax.ShapeDtypeStruct((batch_size, *IMG), jnp.float32),
            jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            jax.ShapeDtypeStruct((batch_size,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )

    def eval_args(total):
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            jax.ShapeDtypeStruct((eval_batch, *IMG), jnp.float32),
            jax.ShapeDtypeStruct((eval_batch,), jnp.int32),
            jax.ShapeDtypeStruct((eval_batch,), jnp.float32),
        )

    return specs, train, eval_step, train_args, eval_args


def flops_per_train_step(batch_size: int) -> int:
    """Analytic FLOP estimate (fwd+bwd ~ 3x fwd) for GPU-hour simulation."""
    conv1 = 32 * 32 * C1 * (3 * 3 * 3) * 2
    conv2 = 16 * 16 * C2 * (3 * 3 * C1) * 2
    fc1 = (8 * 8 * C2) * HID * 2
    fc2 = HID * NUM_CLASSES * 2
    return 3 * batch_size * (conv1 + conv2 + fc1 + fc2)
