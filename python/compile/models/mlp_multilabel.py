"""FLAIR benchmark stand-in: multi-label classifier over precomputed
features (paper App. C.7 uses a pretrained ResNet18 + linear head on 17
coarse labels; our substitution keeps the trained part — features -> MLP
trunk -> 17 sigmoid heads — and replaces the frozen pretrained backbone
with a synthetic feature generator; see DESIGN.md §2).

The eval step additionally returns the raw scores so the Rust side can
compute macro-averaged precision (C-AP / mAP) over the full eval set.
"""

import jax
import jax.numpy as jnp

from ..kernels.fused_linear import fused_linear
from .common import (
    ParamSpec,
    fan_in_std,
    make_train_step,
    sigmoid_bce,
    unflatten,
)

FEAT = 192
HID = 256
LABELS = 17


def param_specs():
    return [
        ParamSpec("fc1_w", (FEAT, HID), "normal", fan_in_std(FEAT)),
        ParamSpec("fc1_b", (HID,), "zeros"),
        ParamSpec("fc2_w", (HID, HID), "normal", fan_in_std(HID)),
        ParamSpec("fc2_b", (HID,), "zeros"),
        ParamSpec("head_w", (HID, LABELS), "normal", fan_in_std(HID)),
        ParamSpec("head_b", (LABELS,), "zeros"),
    ]


def forward(params, x):
    h = fused_linear(x, params["fc1_w"], params["fc1_b"], "relu")
    h = fused_linear(h, params["fc2_w"], params["fc2_b"], "relu")
    return fused_linear(h, params["head_w"], params["head_b"], "id")


def loss_fn(params, x, y, w):
    logits = forward(params, x)
    mean, loss_sum, tp, wsum = sigmoid_bce(logits, y, w)
    return mean, (loss_sum, tp, wsum)


def make_steps(batch_size: int, eval_batch: int):
    specs = param_specs()
    train = make_train_step(loss_fn, specs)

    def eval_step(flat, x, y, w):
        params = unflatten(flat, specs)
        logits = forward(params, x)
        _, (loss_sum, tp, wsum) = loss_fn(params, x, y, w)
        return loss_sum, tp, wsum, logits

    def train_args(total):
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            f,
            f,
            jax.ShapeDtypeStruct((batch_size, FEAT), jnp.float32),
            jax.ShapeDtypeStruct((batch_size, LABELS), jnp.float32),
            jax.ShapeDtypeStruct((batch_size,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )

    def eval_args(total):
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            jax.ShapeDtypeStruct((eval_batch, FEAT), jnp.float32),
            jax.ShapeDtypeStruct((eval_batch, LABELS), jnp.float32),
            jax.ShapeDtypeStruct((eval_batch,), jnp.float32),
        )

    return specs, train, eval_step, train_args, eval_args


def flops_per_train_step(batch_size: int) -> int:
    fwd = FEAT * HID * 2 + HID * HID * 2 + HID * LABELS * 2
    return 3 * batch_size * fwd
