"""StackOverflow benchmark transformer LM (paper App. C.6, Table 9).

Next-word prediction: 96-d embeddings, 3 encoder layers, 8 heads, 1536-d
feedforward, sequence length 20, tied input/output embedding — 1.96M
parameters, matching the paper's "transformer model with 1,962,912
parameters" up to the vocab substitution (synthetic Zipf 10k vocab).

The feedforward blocks and the tied logit projection run on the L1 Pallas
`fused_linear`/`matmul` kernels; attention einsums stay in XLA (they are
small at T=20 and fuse well).
"""

import math

import jax
import jax.numpy as jnp

from ..kernels.fused_linear import fused_linear, matmul
from .common import ParamSpec, fan_in_std, make_train_step, unflatten

VOCAB = 10_000
EMB = 96
HEADS = 8
FF = 1536
LAYERS = 3
SEQ = 20  # tokens per example fed to the model (predict 1..SEQ-1)
PAD = 0


def param_specs(vocab=VOCAB, layers=LAYERS):
    specs = [
        ParamSpec("embed", (vocab, EMB), "normal", 0.02),
        ParamSpec("pos", (SEQ, EMB), "normal", 0.01),
    ]
    for i in range(layers):
        p = f"l{i}_"
        specs += [
            ParamSpec(p + "qkv_w", (EMB, 3 * EMB), "normal", fan_in_std(EMB, gain=1.0)),
            ParamSpec(p + "qkv_b", (3 * EMB,), "zeros"),
            ParamSpec(p + "proj_w", (EMB, EMB), "normal", fan_in_std(EMB, gain=1.0)),
            ParamSpec(p + "proj_b", (EMB,), "zeros"),
            ParamSpec(p + "ln1_g", (EMB,), "ones"),
            ParamSpec(p + "ln1_b", (EMB,), "zeros"),
            ParamSpec(p + "ff1_w", (EMB, FF), "normal", fan_in_std(EMB)),
            ParamSpec(p + "ff1_b", (FF,), "zeros"),
            ParamSpec(p + "ff2_w", (FF, EMB), "normal", fan_in_std(FF)),
            ParamSpec(p + "ff2_b", (EMB,), "zeros"),
            ParamSpec(p + "ln2_g", (EMB,), "ones"),
            ParamSpec(p + "ln2_b", (EMB,), "zeros"),
        ]
    specs += [
        ParamSpec("lnf_g", (EMB,), "ones"),
        ParamSpec("lnf_b", (EMB,), "zeros"),
    ]
    return specs


def _ln(x, g, b):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * g + b


def _attention(x, p, prefix, mask):
    B, T, E = x.shape
    hd = E // HEADS
    qkv = (x.reshape(B * T, E) @ p[prefix + "qkv_w"] + p[prefix + "qkv_b"]).reshape(
        B, T, 3, HEADS, hd
    )
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    att = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(causal[None, None] & mask[:, None, None, :], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, E)
    return (out.reshape(B * T, E) @ p[prefix + "proj_w"] + p[prefix + "proj_b"]).reshape(
        B, T, E
    )


def forward(params, tokens):
    """tokens [B, SEQ] i32 -> logits [B, SEQ-1, VOCAB] predicting tokens[1:]."""
    B, T = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :T]
    mask = tokens != PAD
    for i in range(LAYERS):
        p = f"l{i}_"
        x = x + _attention(_ln(x, params[p + "ln1_g"], params[p + "ln1_b"]), params, p, mask)
        h = _ln(x, params[p + "ln2_g"], params[p + "ln2_b"])
        h2 = fused_linear(h.reshape(B * T, EMB), params[p + "ff1_w"], params[p + "ff1_b"], "relu")
        h2 = fused_linear(h2, params[p + "ff2_w"], params[p + "ff2_b"], "id")
        x = x + h2.reshape(B, T, EMB)
    x = _ln(x, params["lnf_g"], params["lnf_b"])
    # tied output embedding, on the pallas matmul
    logits = matmul(x[:, :-1].reshape(B * (T - 1), EMB), params["embed"].T)
    return logits.reshape(B, T - 1, -1)


def loss_fn(params, tokens, w):
    """Causal LM loss. `w` [B] is the per-example mask; token-level mask is
    target != PAD. Returns sums over *tokens* so perplexity = exp(loss_sum/wsum)."""
    logits = forward(params, tokens)
    targets = tokens[:, 1:]
    tok_mask = (targets != PAD).astype(jnp.float32) * w[:, None]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    per_tok = (logz - ll) * tok_mask
    loss_sum = jnp.sum(per_tok)
    wsum = jnp.sum(tok_mask)
    correct = jnp.sum(
        (jnp.argmax(logits, -1) == targets).astype(jnp.float32) * tok_mask
    )
    return loss_sum / jnp.maximum(wsum, 1e-12), (loss_sum, correct, wsum)


def make_steps(batch_size: int, eval_batch: int):
    specs = param_specs()
    train = make_train_step(loss_fn, specs)

    def eval_step(flat, tokens, w):
        params = unflatten(flat, specs)
        _, (loss_sum, correct, wsum) = loss_fn(params, tokens, w)
        return loss_sum, correct, wsum

    def train_args(total):
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            f,
            f,
            jax.ShapeDtypeStruct((batch_size, SEQ), jnp.int32),
            jax.ShapeDtypeStruct((batch_size,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )

    def eval_args(total):
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            jax.ShapeDtypeStruct((eval_batch, SEQ), jnp.int32),
            jax.ShapeDtypeStruct((eval_batch,), jnp.float32),
        )

    return specs, train, eval_step, train_args, eval_args


def flops_per_train_step(batch_size: int) -> int:
    per_tok = (
        4 * EMB * EMB * 2  # qkv + proj
        + 2 * SEQ * EMB * 2  # attention scores + mix
        + 2 * EMB * FF * 2  # ff
    ) * LAYERS + EMB * VOCAB * 2  # logits
    return 3 * batch_size * SEQ * per_tok
