"""LLM benchmark stand-in: frozen-base transformer + LoRA rank-8 adapters
(paper App. C.8: TinyLlama-1.1B with LoRA r=8, bf16; substitution per
DESIGN.md §2 — a small causal transformer whose *frozen base weights are a
runtime input* while only the adapters live in the trainable flat vector,
exercising the identical adapter-only FL code path at CPU-simulable size).
"""

import math

import jax
import jax.numpy as jnp

from ..kernels.fused_linear import fused_linear, matmul
from .common import ParamSpec, fan_in_std, unflatten

VOCAB = 2_000
EMB = 64
HEADS = 4
FF = 256
LAYERS = 2
SEQ = 32
RANK = 8
ALPHA = 16.0
PAD = 0


def base_param_specs():
    specs = [
        ParamSpec("embed", (VOCAB, EMB), "normal", 0.02),
        ParamSpec("pos", (SEQ, EMB), "normal", 0.01),
    ]
    for i in range(LAYERS):
        p = f"l{i}_"
        specs += [
            ParamSpec(p + "qkv_w", (EMB, 3 * EMB), "normal", fan_in_std(EMB, gain=1.0)),
            ParamSpec(p + "qkv_b", (3 * EMB,), "zeros"),
            ParamSpec(p + "proj_w", (EMB, EMB), "normal", fan_in_std(EMB, gain=1.0)),
            ParamSpec(p + "proj_b", (EMB,), "zeros"),
            ParamSpec(p + "ln1_g", (EMB,), "ones"),
            ParamSpec(p + "ln1_b", (EMB,), "zeros"),
            ParamSpec(p + "ff1_w", (EMB, FF), "normal", fan_in_std(EMB)),
            ParamSpec(p + "ff1_b", (FF,), "zeros"),
            ParamSpec(p + "ff2_w", (FF, EMB), "normal", fan_in_std(FF)),
            ParamSpec(p + "ff2_b", (EMB,), "zeros"),
            ParamSpec(p + "ln2_g", (EMB,), "ones"),
            ParamSpec(p + "ln2_b", (EMB,), "zeros"),
        ]
    specs += [ParamSpec("lnf_g", (EMB,), "ones"), ParamSpec("lnf_b", (EMB,), "zeros")]
    return specs


def adapter_param_specs():
    """LoRA A/B on the qkv and ff1 projections. A ~ N(0, 1/r), B = 0 so the
    adapter starts as the identity perturbation (standard LoRA init)."""
    specs = []
    for i in range(LAYERS):
        p = f"l{i}_"
        specs += [
            ParamSpec(p + "qkv_A", (EMB, RANK), "normal", 1.0 / RANK),
            ParamSpec(p + "qkv_B", (RANK, 3 * EMB), "zeros"),
            ParamSpec(p + "ff1_A", (EMB, RANK), "normal", 1.0 / RANK),
            ParamSpec(p + "ff1_B", (RANK, FF), "zeros"),
        ]
    return specs


def _ln(x, g, b):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * g + b


def _attention(x, qkv_w, qkv_b, proj_w, proj_b, mask):
    B, T, E = x.shape
    hd = E // HEADS
    qkv = (x.reshape(B * T, E) @ qkv_w + qkv_b).reshape(B, T, 3, HEADS, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    att = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(causal[None, None] & mask[:, None, None, :], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, E)
    return (out.reshape(B * T, E) @ proj_w + proj_b).reshape(B, T, E)


def forward(adapters, base, tokens):
    B, T = tokens.shape
    x = base["embed"][tokens] + base["pos"][None, :T]
    mask = tokens != PAD
    scale = ALPHA / RANK
    for i in range(LAYERS):
        p = f"l{i}_"
        qkv_w = base[p + "qkv_w"] + scale * (adapters[p + "qkv_A"] @ adapters[p + "qkv_B"])
        x = x + _attention(
            _ln(x, base[p + "ln1_g"], base[p + "ln1_b"]),
            qkv_w, base[p + "qkv_b"], base[p + "proj_w"], base[p + "proj_b"],
            mask,
        )
        h = _ln(x, base[p + "ln2_g"], base[p + "ln2_b"])
        ff1_w = base[p + "ff1_w"] + scale * (adapters[p + "ff1_A"] @ adapters[p + "ff1_B"])
        h2 = fused_linear(h.reshape(B * T, EMB), ff1_w, base[p + "ff1_b"], "gelu")
        h2 = fused_linear(h2, base[p + "ff2_w"], base[p + "ff2_b"], "id")
        x = x + h2.reshape(B, T, EMB)
    x = _ln(x, base["lnf_g"], base["lnf_b"])
    logits = matmul(x[:, :-1].reshape(B * (T - 1), EMB), base["embed"].T)
    return logits.reshape(B, T - 1, -1)


def loss_fn(adapters, base, tokens, w):
    logits = forward(adapters, base, tokens)
    targets = tokens[:, 1:]
    tok_mask = (targets != PAD).astype(jnp.float32) * w[:, None]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss_sum = jnp.sum((logz - ll) * tok_mask)
    wsum = jnp.sum(tok_mask)
    correct = jnp.sum(
        (jnp.argmax(logits, -1) == targets).astype(jnp.float32) * tok_mask
    )
    return loss_sum / jnp.maximum(wsum, 1e-12), (loss_sum, correct, wsum)


def make_steps(batch_size: int, eval_batch: int):
    specs = adapter_param_specs()
    bspecs = base_param_specs()

    def train(flat, base_flat, global_flat, c_diff, tokens, w, lr, mu):
        base = unflatten(base_flat, bspecs)

        def obj(f):
            return loss_fn(unflatten(f, specs), base, tokens, w)

        grads, (loss_sum, correct, wsum) = jax.grad(obj, has_aux=True)(flat)
        g = grads + mu * (flat - global_flat) + c_diff
        return flat - lr * g, loss_sum, correct, wsum

    def eval_step(flat, base_flat, tokens, w):
        base = unflatten(base_flat, bspecs)
        _, (loss_sum, correct, wsum) = loss_fn(unflatten(flat, specs), base, tokens, w)
        return loss_sum, correct, wsum

    def train_args(total):
        base_total = sum(s.size for s in bspecs)
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            jax.ShapeDtypeStruct((base_total,), jnp.float32),
            f,
            f,
            jax.ShapeDtypeStruct((batch_size, SEQ), jnp.int32),
            jax.ShapeDtypeStruct((batch_size,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )

    def eval_args(total):
        base_total = sum(s.size for s in bspecs)
        f = jax.ShapeDtypeStruct((total,), jnp.float32)
        return (
            f,
            jax.ShapeDtypeStruct((base_total,), jnp.float32),
            jax.ShapeDtypeStruct((eval_batch, SEQ), jnp.int32),
            jax.ShapeDtypeStruct((eval_batch,), jnp.float32),
        )

    return specs, train, eval_step, train_args, eval_args


def flops_per_train_step(batch_size: int) -> int:
    per_tok = (
        4 * EMB * EMB * 2
        + 2 * SEQ * EMB * 2
        + 2 * EMB * FF * 2
        + 2 * (EMB * RANK + RANK * 3 * EMB)
    ) * LAYERS + EMB * VOCAB * 2
    return 3 * batch_size * SEQ * per_tok
