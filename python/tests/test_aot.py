"""AOT pipeline: manifest consistency and HLO-text emission.

These tests validate the python->rust interchange contract without
requiring rust: the manifest's shapes must match what the step functions
actually take/return, and the emitted HLO must be text (parseable header,
ENTRY, no serialized-proto bytes).
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import MODELS

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke(tmp_path):
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_build_single_model(tmp_path):
    manifest = aot.build_all(str(tmp_path), only=["mlp_flair"], verbose=False)
    assert set(manifest["models"]) == {"mlp_flair"}
    arts = manifest["models"]["mlp_flair"]["artifacts"]
    for key in ("train", "eval", "clip"):
        art = manifest["artifacts"][arts[key]]
        p = tmp_path / art["file"]
        assert p.exists()
        head = p.read_text()[:200]
        assert head.startswith("HloModule")

    m = manifest["models"]["mlp_flair"]
    assert m["param_count"] == sum(
        e["size"] for e in m["layout"]
    )
    # train inputs: params, global, c_diff, x, y, w, lr, mu
    tr = manifest["artifacts"][arts["train"]]
    assert len(tr["inputs"]) == 8
    assert tr["inputs"][0]["shape"] == [m["param_count"]]
    assert tr["outputs"][0]["shape"] == [m["param_count"]]
    # clip: (v, bound) -> (clipped, norm)
    cl = manifest["artifacts"][arts["clip"]]
    assert cl["inputs"][0]["shape"] == [m["param_count"]]
    assert cl["outputs"][1]["shape"] == []


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_models_present(self, manifest):
        assert set(manifest["models"]) == set(MODELS)

    def test_artifact_files_exist_and_are_text(self, manifest):
        for art in manifest["artifacts"].values():
            p = os.path.join(ART_DIR, art["file"])
            assert os.path.exists(p), p
            with open(p) as f:
                assert f.read(9) == "HloModule"

    def test_layouts_cover_param_count(self, manifest):
        for m in manifest["models"].values():
            end = max(e["offset"] + e["size"] for e in m["layout"])
            assert end == m["param_count"]

    def test_flops_positive(self, manifest):
        for m in manifest["models"].values():
            assert m["flops_per_train_step"] > 0
