"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; assert_allclose against the oracle is the
core correctness signal the AOT path relies on (the same kernels lower
into every model artifact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.clip_scale import clip_scale
from compile.kernels.fused_linear import fused_linear, matmul

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def vec_and_bound(draw):
    n = draw(st.integers(min_value=1, max_value=5000))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    scale = draw(st.sampled_from([1e-3, 1.0, 10.0, 1e3]))
    bound = draw(st.sampled_from([0.1, 0.4, 1.0, 100.0]))
    rng = np.random.default_rng(seed)
    v = (rng.normal(size=(n,)) * scale).astype(np.float32)
    return v, np.float32(bound)


class TestClipScale:
    @settings(**SETTINGS)
    @given(vec_and_bound())
    def test_matches_ref(self, vb):
        v, bound = vb
        got, gn = clip_scale(jnp.asarray(v), bound, block=1024)
        want, wn = ref.clip_scale_ref(jnp.asarray(v), bound)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(gn), float(wn), rtol=1e-5)

    @settings(**SETTINGS)
    @given(vb=vec_and_bound())
    def test_norm_bound_invariant(self, vb):
        """Property: the clipped vector's norm never exceeds bound (+eps)."""
        v, bound = vb
        got, _ = clip_scale(jnp.asarray(v), bound, block=512)
        out_norm = float(jnp.linalg.norm(got))
        assert out_norm <= float(bound) * (1 + 1e-4)

    def test_below_bound_unchanged(self):
        v = jnp.asarray([0.1, -0.2, 0.05], jnp.float32)
        got, n = clip_scale(v, 1.0, block=4)
        np.testing.assert_allclose(np.array(got), np.array(v), rtol=1e-6)
        assert float(n) < 1.0

    def test_zero_vector(self):
        v = jnp.zeros((17,), jnp.float32)
        got, n = clip_scale(v, 0.5, block=8)
        assert float(n) == 0.0
        np.testing.assert_array_equal(np.array(got), np.zeros(17, np.float32))

    def test_exact_block_multiple(self):
        v = jnp.ones((2048,), jnp.float32)
        got, n = clip_scale(v, 1.0, block=1024)
        np.testing.assert_allclose(float(n), np.sqrt(2048.0), rtol=1e-6)
        np.testing.assert_allclose(
            float(jnp.linalg.norm(got)), 1.0, rtol=1e-5
        )

    def test_large_default_block(self):
        rng = np.random.default_rng(7)
        v = jnp.asarray(rng.normal(size=(300_000,)).astype(np.float32))
        got, n = clip_scale(v, 1.0)
        want, wn = ref.clip_scale_ref(v, 1.0)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


@st.composite
def mm_shapes(draw):
    m = draw(st.integers(min_value=1, max_value=200))
    k = draw(st.integers(min_value=1, max_value=200))
    n = draw(st.integers(min_value=1, max_value=200))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return m, k, n, seed


def _rand_mm(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    b = rng.normal(size=(n,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)


class TestFusedLinear:
    @settings(**SETTINGS)
    @given(mm_shapes(), st.sampled_from(["id", "relu", "gelu"]))
    def test_matches_ref(self, shapes, act):
        x, w, b = _rand_mm(*shapes)
        got = fused_linear(x, w, b, act)
        want = ref.fused_linear_ref(x, w, b, act)
        np.testing.assert_allclose(
            np.array(got), np.array(want), rtol=1e-4, atol=1e-4
        )

    @settings(**SETTINGS)
    @given(mm_shapes())
    def test_matmul_matches_ref(self, shapes):
        x, w, _ = _rand_mm(*shapes)
        got = matmul(x, w)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(
            np.array(got), np.array(want), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("act", ["id", "relu", "gelu"])
    def test_gradients_match_ref(self, act):
        x, w, b = _rand_mm(13, 37, 11, 3)

        def f_kernel(x, w, b):
            return jnp.sum(jnp.sin(fused_linear(x, w, b, act)))

        def f_ref(x, w, b):
            return jnp.sum(jnp.sin(ref.fused_linear_ref(x, w, b, act)))

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
        for a, c in zip(gk, gr):
            np.testing.assert_allclose(
                np.array(a), np.array(c), rtol=1e-3, atol=1e-4
            )

    def test_matmul_gradients(self):
        x, w, _ = _rand_mm(9, 21, 5, 11)

        def f(x, w):
            return jnp.sum(matmul(x, w) ** 2)

        def f_ref(x, w):
            return jnp.sum(ref.matmul_ref(x, w) ** 2)

        gk = jax.grad(f, argnums=(0, 1))(x, w)
        gr = jax.grad(f_ref, argnums=(0, 1))(x, w)
        for a, c in zip(gk, gr):
            np.testing.assert_allclose(np.array(a), np.array(c), rtol=1e-3, atol=1e-3)

    def test_tile_exact_multiples(self):
        # shapes exactly on the (128,128,128) tile grid
        x, w, b = _rand_mm(128, 256, 128, 5)
        got = fused_linear(x, w, b, "relu")
        want = ref.fused_linear_ref(x, w, b, "relu")
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)

    def test_jit_compatible(self):
        x, w, b = _rand_mm(4, 8, 3, 9)
        got = jax.jit(lambda x, w, b: fused_linear(x, w, b, "relu"))(x, w, b)
        want = ref.fused_linear_ref(x, w, b, "relu")
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)
