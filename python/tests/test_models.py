"""L2 correctness: model step semantics every algorithm relies on.

Checks, per model: parameter-count bookkeeping, gradient finiteness, loss
decrease under local SGD, the unified-step algebra (mu / c_diff terms),
and metric sufficient statistics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import MODELS
from compile.models import cnn, lora_lm, mlp_multilabel, transformer
from compile.models.common import manifest_layout, unflatten


def _init_flat(specs, seed=0):
    rng = np.random.default_rng(seed)
    parts = []
    for s in specs:
        if s.init == "zeros":
            parts.append(np.zeros(s.size, np.float32))
        elif s.init == "ones":
            parts.append(np.ones(s.size, np.float32))
        else:
            parts.append(rng.normal(0, s.std, s.size).astype(np.float32))
    return jnp.asarray(np.concatenate(parts))


def _batch_for(name, mdef, specs, seed=1):
    rng = np.random.default_rng(seed)
    B = mdef.train_batch
    if name == "cnn_c10":
        x = jnp.asarray(rng.normal(size=(B, 32, 32, 3)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, B).astype(np.int32))
        w = jnp.ones((B,), jnp.float32)
        return (x, y, w)
    if name == "lm_so":
        toks = rng.integers(1, transformer.VOCAB, (B, transformer.SEQ)).astype(np.int32)
        return (jnp.asarray(toks), jnp.ones((B,), jnp.float32))
    if name == "mlp_flair":
        x = jnp.asarray(rng.normal(size=(B, mlp_multilabel.FEAT)).astype(np.float32))
        y = jnp.asarray((rng.random((B, mlp_multilabel.LABELS)) < 0.2).astype(np.float32))
        return (x, y, jnp.ones((B,), jnp.float32))
    if name == "lora_llm":
        toks = rng.integers(1, lora_lm.VOCAB, (B, lora_lm.SEQ)).astype(np.int32)
        return (jnp.asarray(toks), jnp.ones((B,), jnp.float32))
    raise KeyError(name)


@pytest.fixture(scope="module")
def built():
    out = {}
    for name, mdef in MODELS.items():
        specs, train, ev, targs, eargs = mdef.make_steps(
            mdef.train_batch, mdef.eval_batch
        )
        out[name] = (mdef, specs, train, ev)
    return out


PARAM_COUNTS = {
    "cnn_c10": None,  # checked for >0 only
    "lm_so": 1_964_640,  # ~1.96M, paper says 1,962,912 for its vocab
    "mlp_flair": None,
    "lora_llm": None,
}


class TestLayout:
    @pytest.mark.parametrize("name", list(MODELS))
    def test_layout_contiguous(self, name, built):
        _, specs, _, _ = built[name]
        entries, total = manifest_layout(specs)
        off = 0
        for e in entries:
            assert e["offset"] == off
            assert e["size"] == int(np.prod(e["shape"])) if e["shape"] else 1
            off += e["size"]
        assert off == total > 0

    def test_lm_param_count_near_paper(self, built):
        _, specs, _, _ = built["lm_so"]
        total = sum(s.size for s in specs)
        # paper: 1,962,912 parameters; ours differs only by vocab rounding
        assert abs(total - 1_962_912) / 1_962_912 < 0.01

    @pytest.mark.parametrize("name", list(MODELS))
    def test_unflatten_roundtrip(self, name, built):
        _, specs, _, _ = built[name]
        flat = _init_flat(specs)
        tree = unflatten(flat, specs)
        rec = jnp.concatenate([tree[s.name].reshape(-1) for s in specs])
        np.testing.assert_array_equal(np.array(rec), np.array(flat))


class TestTrainStep:
    @pytest.mark.parametrize("name", list(MODELS))
    def test_loss_decreases(self, name, built):
        mdef, specs, train, _ = built[name]
        flat = _init_flat(specs)
        batch = _batch_for(name, mdef, specs)
        zeros = jnp.zeros_like(flat)
        extra = ()
        if mdef.has_base:
            base = _init_flat(lora_lm.base_param_specs(), seed=42)
            extra = (base,)
        lr, mu = jnp.float32(0.1), jnp.float32(0.0)

        def run(f):
            if mdef.has_base:
                return train(f, extra[0], zeros, zeros, *batch, lr, mu)
            return train(f, zeros, zeros, *batch, lr, mu)

        losses = []
        for _ in range(6):
            flat, loss_sum, _, wsum = run(flat)
            losses.append(float(loss_sum) / float(wsum))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses

    @pytest.mark.parametrize("name", list(MODELS))
    def test_zero_lr_is_identity(self, name, built):
        mdef, specs, train, _ = built[name]
        flat = _init_flat(specs)
        batch = _batch_for(name, mdef, specs)
        zeros = jnp.zeros_like(flat)
        args = (flat, zeros, zeros, *batch, jnp.float32(0.0), jnp.float32(0.0))
        if mdef.has_base:
            base = _init_flat(lora_lm.base_param_specs(), seed=42)
            args = (flat, base, zeros, zeros, *batch, jnp.float32(0.0), jnp.float32(0.0))
        new, *_ = train(*args)
        np.testing.assert_array_equal(np.array(new), np.array(flat))

    def test_prox_term_pulls_toward_global(self, built):
        """With huge mu the step should move params toward global."""
        mdef, specs, train, _ = built["mlp_flair"]
        flat = _init_flat(specs, seed=0)
        glob = _init_flat(specs, seed=99)
        batch = _batch_for("mlp_flair", mdef, specs)
        zeros = jnp.zeros_like(flat)
        lr = jnp.float32(0.01)
        new_noprox, *_ = train(flat, glob, zeros, *batch, lr, jnp.float32(0.0))
        new_prox, *_ = train(flat, glob, zeros, *batch, lr, jnp.float32(100.0))
        d_noprox = float(jnp.linalg.norm(new_noprox - glob))
        d_prox = float(jnp.linalg.norm(new_prox - glob))
        assert d_prox < d_noprox

    def test_cdiff_shifts_update_exactly(self, built):
        """SCAFFOLD algebra: step(c_diff) == step(0) - lr*c_diff."""
        mdef, specs, train, _ = built["mlp_flair"]
        flat = _init_flat(specs)
        batch = _batch_for("mlp_flair", mdef, specs)
        zeros = jnp.zeros_like(flat)
        rng = np.random.default_rng(5)
        cd = jnp.asarray(rng.normal(size=flat.shape).astype(np.float32))
        lr = jnp.float32(0.05)
        a, *_ = train(flat, zeros, zeros, *batch, lr, jnp.float32(0.0))
        b, *_ = train(flat, zeros, cd, *batch, lr, jnp.float32(0.0))
        np.testing.assert_allclose(
            np.array(b), np.array(a - lr * cd), rtol=1e-4, atol=1e-5
        )

    def test_mask_excludes_examples(self, built):
        """A fully-masked batch must produce a zero gradient step."""
        mdef, specs, train, _ = built["cnn_c10"]
        flat = _init_flat(specs)
        x, y, _ = _batch_for("cnn_c10", mdef, specs)
        w0 = jnp.zeros((mdef.train_batch,), jnp.float32)
        zeros = jnp.zeros_like(flat)
        new, loss_sum, correct, wsum = train(
            flat, zeros, zeros, x, y, w0, jnp.float32(0.1), jnp.float32(0.0)
        )
        assert float(wsum) == 0.0
        assert float(loss_sum) == 0.0
        np.testing.assert_allclose(np.array(new), np.array(flat), atol=1e-6)


class TestEvalStep:
    @pytest.mark.parametrize("name", list(MODELS))
    def test_eval_stats_shapes(self, name, built):
        mdef, specs, _, ev = built[name]
        flat = _init_flat(specs)
        rng = np.random.default_rng(3)
        B = mdef.eval_batch
        if name == "cnn_c10":
            args = (
                flat,
                jnp.asarray(rng.normal(size=(B, 32, 32, 3)).astype(np.float32)),
                jnp.asarray(rng.integers(0, 10, B).astype(np.int32)),
                jnp.ones((B,), jnp.float32),
            )
        elif name == "lm_so":
            args = (
                flat,
                jnp.asarray(rng.integers(1, transformer.VOCAB, (B, transformer.SEQ)).astype(np.int32)),
                jnp.ones((B,), jnp.float32),
            )
        elif name == "mlp_flair":
            args = (
                flat,
                jnp.asarray(rng.normal(size=(B, mlp_multilabel.FEAT)).astype(np.float32)),
                jnp.asarray((rng.random((B, mlp_multilabel.LABELS)) < 0.2).astype(np.float32)),
                jnp.ones((B,), jnp.float32),
            )
        else:
            base = _init_flat(lora_lm.base_param_specs(), seed=42)
            args = (
                flat,
                base,
                jnp.asarray(rng.integers(1, lora_lm.VOCAB, (B, lora_lm.SEQ)).astype(np.int32)),
                jnp.ones((B,), jnp.float32),
            )
        out = ev(*args)
        loss_sum, stat, wsum = out[0], out[1], out[2]
        assert np.isfinite(float(loss_sum))
        assert float(wsum) > 0
        if name == "mlp_flair":
            scores = out[3]
            assert scores.shape == (B, mlp_multilabel.LABELS)

    def test_untrained_lm_perplexity_near_vocab(self, built):
        """Random-init LM perplexity should be ~vocab size (uniform)."""
        mdef, specs, _, ev = built["lm_so"]
        flat = _init_flat(specs)
        rng = np.random.default_rng(4)
        B = mdef.eval_batch
        toks = jnp.asarray(
            rng.integers(1, transformer.VOCAB, (B, transformer.SEQ)).astype(np.int32)
        )
        loss_sum, _, wsum = ev(flat, toks, jnp.ones((B,), jnp.float32))
        ppl = float(jnp.exp(loss_sum / wsum))
        assert 0.2 * transformer.VOCAB < ppl < 5 * transformer.VOCAB
