//! Bench: the PJRT execution hot path (one local train step / eval batch
//! / L1 clip kernel per benchmark model). These are the irreducible
//! device costs the simulation wraps; everything in the speed tables sits
//! on top of them. Paper analogue: the per-step GPU time underlying
//! Tables 1–2.
//!
//! Emits `BENCH_hotpath.json` (ns/op + heap bytes/op via
//! `CountingAlloc`) — written even when the HLO artifacts are absent, so
//! downstream tooling can rely on the file existing.

use pfl::fl::context::LocalParams;
use pfl::fl::model::HloModel;
use pfl::fl::Model;
use pfl::runtime::{Manifest, Runtime};
use pfl::util::bench::{
    bench_per_op_alloc, black_box, write_bench_json, BenchRecord, CountingAlloc,
};
use pfl::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let mut records: Vec<BenchRecord> = Vec::new();
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping runtime_hotpath: run `make artifacts` first");
            write_bench_json("BENCH_hotpath.json", &records)?;
            return Ok(());
        }
    };
    let rt = Runtime::new(manifest)?;
    println!("# runtime hot path (CPU PJRT, interpret-mode Pallas)");

    for name in ["cnn_c10", "mlp_flair", "lm_so", "lora_llm"] {
        let mut model = HloModel::new(&rt, name, 1)?;
        let data = match name {
            "cnn_c10" => pfl::data::FederatedDataset::user_data(
                &pfl::data::SynthCifar::new(4, 30, None, 3),
                0,
            ),
            "mlp_flair" => pfl::data::FederatedDataset::user_data(
                &pfl::data::SynthFlair::new(4, None, 3),
                0,
            ),
            "lm_so" => pfl::data::FederatedDataset::user_data(
                &pfl::data::SynthText::new(4, 3),
                0,
            ),
            _ => pfl::data::FederatedDataset::user_data(
                &pfl::data::SynthInstruct::new(pfl::data::InstructFlavor::Alpaca, 200, 3),
                0,
            ),
        };
        // one user's local optimization (epochs=1)
        let p = LocalParams { epochs: 1, batch_size: 16, lr: 0.1, mu: 0.0, max_steps: 0 };
        let (r, alloc) =
            bench_per_op_alloc(&format!("{name}/train_local(1 user)"), 2, 10, 1, || {
                let out = model.train_local(&data, &p, None, 7).unwrap();
                black_box(out.loss_sum);
            });
        records.push(BenchRecord::new(&r, alloc));

        let (r, alloc) =
            bench_per_op_alloc(&format!("{name}/evaluate(1 user)"), 2, 10, 1, || {
                let m = model.evaluate(&data, None).unwrap();
                black_box(m.get("loss"));
            });
        records.push(BenchRecord::new(&r, alloc));

        // the L1 Pallas clip kernel on a param-sized vector
        let mut rng = Rng::seed_from_u64(0);
        let template: Vec<f32> =
            (0..model.param_count()).map(|_| rng.normal() as f32 * 0.01).collect();
        let kernel = model.clip_kernel().unwrap();
        let (r, alloc) = bench_per_op_alloc(
            &format!("{name}/clip_kernel({} params)", template.len()),
            2,
            10,
            1,
            || {
                let mut v = template.clone();
                let norm = kernel.clip(&mut v, 0.5).unwrap();
                black_box(norm);
            },
        );
        records.push(BenchRecord::new(&r, alloc));
    }

    write_bench_json("BENCH_hotpath.json", &records)?;
    println!("wrote BENCH_hotpath.json");
    Ok(())
}
