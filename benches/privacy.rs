//! Bench: the DP hot paths — noise generation on model-sized aggregates
//! (once per round; paper §4.1 shows DP adds only ~9% wall-clock on
//! FLAIR), BMF's correlated-noise mixing, and accountant ε evaluations
//! (run once per calibration, so seconds are acceptable).

use pfl::fl::context::{CentralContext, LocalParams};
use pfl::fl::model::RustClip;
use pfl::fl::postprocess::{Postprocessor, PpEnv};
use pfl::fl::stats::Statistics;
use pfl::privacy::{
    Accountant, AccountantParams, BandedMatrixFactorization, GaussianMechanism, PldAccountant,
    RdpAccountant,
};
use pfl::util::bench::{bench, black_box};
use pfl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dims = [119_569usize, 1_964_640]; // mlp_flair / lm_so param counts
    let ctx = CentralContext::train(5, 50, LocalParams::default(), 1);

    for &d in &dims {
        let gauss = GaussianMechanism::new(1.0, 1.0, 0.1);
        let mut rng = Rng::seed_from_u64(0);
        bench(&format!("gaussian/server-noise d={d}"), 2, 10, || {
            let mut s = Statistics::new_update(vec![0.01f32; d], 50.0);
            let mut env = PpEnv { clip: &RustClip, rng: &mut rng, user_len: 0 };
            gauss.postprocess_server(&mut s, &ctx, &mut env).unwrap();
            black_box(s.weight);
        });

        let bmf = BandedMatrixFactorization::new(1.0, 1.0, 0.1, 8);
        bench(&format!("banded-mf/server-noise d={d} band=8"), 2, 10, || {
            let mut s = Statistics::new_update(vec![0.01f32; d], 50.0);
            let mut env = PpEnv { clip: &RustClip, rng: &mut rng, user_len: 0 };
            bmf.postprocess_server(&mut s, &ctx, &mut env).unwrap();
            black_box(s.weight);
        });

        let clip = GaussianMechanism::new(0.4, 1.0, 0.1);
        bench(&format!("gaussian/user-clip d={d} (rust path)"), 2, 10, || {
            let mut s = Statistics::new_update(vec![0.01f32; d], 1.0);
            let mut env = PpEnv { clip: &RustClip, rng: &mut rng, user_len: 1 };
            clip.postprocess_one_user(&mut s, &ctx, &mut env).unwrap();
            black_box(s.weight);
        });
    }

    println!("# accountant epsilon evaluations (once per calibration step)");
    let p = AccountantParams { sampling_rate: 1e-3, delta: 1e-6, steps: 1500 };
    bench("rdp/epsilon T=1500", 1, 5, || {
        black_box(RdpAccountant.epsilon(0.7, &p));
    });
    bench("pld/epsilon T=1500 (fft)", 1, 3, || {
        black_box(PldAccountant::default().epsilon(0.7, &p));
    });
    Ok(())
}
