//! Bench: the DP hot paths — model-sized noise generation (once per
//! round; the last fully serial hot loop before the counter engine),
//! banded-MF's correlated-noise round (retained ring vs counter
//! regeneration), and accountant ε evaluations (run once per
//! calibration, so seconds are acceptable).
//!
//! Gates (recorded in `BENCH_privacy.json`, asserted where the machine
//! allows):
//!
//! * `noise-fill/ctr-8` ≥ 3× over `noise-fill/serial` at d=1e6 when the
//!   machine has ≥ 8 cores.
//! * banded-MF ring reference allocates the full `band·dim·4` bytes of
//!   resident state on its first round; counter regeneration's per-round
//!   scratch stays under one `NOISE_CHUNK` per thread.

use pfl::fl::context::{CentralContext, LocalParams};
use pfl::fl::model::RustClip;
use pfl::fl::postprocess::{Postprocessor, PpEnv};
use pfl::fl::stats::Statistics;
use pfl::privacy::{
    Accountant, AccountantParams, BandedMatrixFactorization, PldAccountant, RdpAccountant,
};
use pfl::tensor::ops;
use pfl::util::bench::{
    alloc_bytes_now, bench, black_box, write_bench_json, BenchRecord, CountingAlloc,
};
use pfl::util::rng::{CtrRng, Rng};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const PAR_THREADS: usize = 8;

fn ctx(t: u64) -> CentralContext {
    CentralContext::train(t, 50, LocalParams::default(), 1)
}

fn env(rng: &mut Rng, threads: usize) -> PpEnv<'_> {
    PpEnv {
        clip: &RustClip,
        rng,
        user_len: 0,
        uid: 0,
        noise_key: 0x5EED,
        noise_threads: threads,
        noise_nanos: 0,
    }
}

fn main() -> anyhow::Result<()> {
    let mut records = Vec::new();

    // --- serial vs counter-parallel Gaussian fill --------------------
    let dims = [100_000usize, 1_000_000, 10_000_000];
    let mut serial_1m = f64::NAN;
    let mut par_1m = f64::NAN;
    for &d in &dims {
        let mut v = vec![0.0f32; d];
        let mut rng = Rng::seed_from_u64(7);
        let iters = if d >= 10_000_000 { 4 } else { 8 };
        let r = bench(&format!("noise-fill/serial d={d}"), 1, iters, || {
            black_box(ops::add_gaussian_noise(&mut v, 1.0, &mut rng));
        });
        if d == 1_000_000 {
            serial_1m = r.median.as_nanos() as f64;
        }
        records.push(BenchRecord::new(&r, 0.0));

        let ctr = CtrRng::new(0x5EED, 1);
        for threads in [1usize, PAR_THREADS] {
            let r = bench(&format!("noise-fill/ctr-{threads} d={d}"), 1, iters, || {
                black_box(ops::add_gaussian_noise_par(&mut v, 1.0, &ctr, threads));
            });
            if d == 1_000_000 && threads == PAR_THREADS {
                par_1m = r.median.as_nanos() as f64;
            }
            records.push(BenchRecord::new(&r, 0.0));
        }
    }

    let speedup = serial_1m / par_1m;
    println!("noise-fill d=1e6: ctr-{PAR_THREADS} speedup {speedup:.2}x over serial");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= PAR_THREADS {
        assert!(
            speedup >= 3.0,
            "parallel fill gate: only {speedup:.2}x over serial at d=1e6 ({cores} cores)"
        );
    } else {
        println!("(speedup gate skipped: {cores} cores < {PAR_THREADS})");
    }

    // --- banded-MF: retained ring vs counter regeneration ------------
    let d = 1_000_000usize;
    let band = 8usize;

    // ring reference (legacy noise_threads = 0): the first round
    // materializes the full band × dim f32 ring
    let ring_mech = BandedMatrixFactorization::new(1.0, 1.0, 0.1, band);
    let mut s = Statistics::new_update(vec![0.01f32; d], 50.0);
    let mut rng = Rng::seed_from_u64(3);
    let a0 = alloc_bytes_now();
    ring_mech.postprocess_server(&mut s, &ctx(0), &mut env(&mut rng, 0)).unwrap();
    let ring_resident = alloc_bytes_now() - a0;
    assert!(
        ring_resident >= (band * d * 4) as u64,
        "ring reference should hold band·dim·4 = {} bytes, saw {ring_resident}",
        band * d * 4
    );
    let mut t = 1u64;
    let r = bench(&format!("banded-mf/ring d={d} band={band}"), 1, 8, || {
        ring_mech.postprocess_server(&mut s, &ctx(t), &mut env(&mut rng, 0)).unwrap();
        t += 1;
        black_box(s.weight);
    });
    records.push(BenchRecord::new(&r, ring_resident as f64));

    // counter regeneration (noise_threads = 8): no retained state; the
    // per-round scratch must stay under one chunk per worker thread
    let regen_mech = BandedMatrixFactorization::new(1.0, 1.0, 0.1, band);
    let mut s = Statistics::new_update(vec![0.01f32; d], 50.0);
    // steady-round scratch, measured on a warm round past the band
    regen_mech
        .postprocess_server(&mut s, &ctx(band as u64), &mut env(&mut rng, PAR_THREADS))
        .unwrap();
    let a0 = alloc_bytes_now();
    regen_mech
        .postprocess_server(&mut s, &ctx(band as u64 + 1), &mut env(&mut rng, PAR_THREADS))
        .unwrap();
    let regen_scratch = alloc_bytes_now() - a0;
    assert!(
        regen_scratch <= (PAR_THREADS * ops::NOISE_CHUNK * 4) as u64,
        "regen scratch gate: {regen_scratch} bytes/round exceeds one chunk per thread ({})",
        PAR_THREADS * ops::NOISE_CHUNK * 4
    );
    let mut t = band as u64 + 2;
    let r = bench(&format!("banded-mf/regen-{PAR_THREADS} d={d} band={band}"), 1, 8, || {
        regen_mech.postprocess_server(&mut s, &ctx(t), &mut env(&mut rng, PAR_THREADS)).unwrap();
        t += 1;
        black_box(s.weight);
    });
    records.push(BenchRecord::new(&r, regen_scratch as f64));
    println!(
        "banded-mf d={d} band={band}: ring resident {ring_resident} B vs regen scratch \
         {regen_scratch} B/round"
    );

    // --- accountant ε evaluations (once per calibration step) --------
    println!("# accountant epsilon evaluations (once per calibration step)");
    let p = AccountantParams { sampling_rate: 1e-3, delta: 1e-6, steps: 1500 };
    let r = bench("rdp/epsilon T=1500", 1, 5, || {
        black_box(RdpAccountant.epsilon(0.7, &p));
    });
    records.push(BenchRecord::new(&r, 0.0));
    let r = bench("pld/epsilon T=1500 (fft)", 1, 3, || {
        black_box(PldAccountant::default().epsilon(0.7, &p));
    });
    records.push(BenchRecord::new(&r, 0.0));

    write_bench_json("BENCH_privacy.json", &records)?;
    println!("wrote BENCH_privacy.json");
    Ok(())
}
