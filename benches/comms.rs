//! Bench: the distributed wire path (`pfl::comms`) — encode/decode of
//! round commands and results at a benchmark model's parameter count,
//! plus a full framed round-trip over a Unix socketpair compared to the
//! in-process mpsc channel it replaces. The codec is pure appends into a
//! reused buffer, so the interesting numbers are ns/op, bytes/round and
//! heap bytes/op (via `CountingAlloc`).
//!
//! Results are written to `BENCH_comms.json` so the perf trajectory is
//! tracked across PRs.

use std::os::unix::net::UnixStream;

use pfl::comms::codec::{
    decode_round, decode_round_result, encode_round, encode_round_result, FRAME_RESULT,
    FRAME_ROUND,
};
use pfl::comms::wire::{read_frame, write_frame, Cursor};
use pfl::fl::context::{CentralContext, LocalParams};
use pfl::fl::stats::{StatValue, Statistics};
use pfl::fl::{Metrics, RoundResult};
use pfl::simsys::{Counters, UserCost};
use pfl::util::bench::{
    bench_per_op_alloc, black_box, write_bench_json, BenchRecord, CountingAlloc,
};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// cnn_c10 parameter count — the model the speed tables run (Table 1).
const DIM: usize = 545_098;

/// A result shaped like one user's fold on the CIFAR-10 benchmark: a
/// dense model-sized partial, train metrics, populated counters and one
/// measured user cost.
fn sample_result(dim: usize) -> RoundResult {
    let mut partial = Statistics::new_update((0..dim).map(|i| i as f32 * 1e-6).collect(), 8.0);
    partial.vecs.insert(
        "c-delta".into(),
        StatValue::Sparse {
            dim: dim as u32,
            idx: vec![3, 999, dim as u32 - 1],
            val: vec![0.5, -0.25, 1.0],
        },
    );
    let mut metrics = Metrics::new();
    metrics.add_central("loss", 12.5, 8.0);
    metrics.add_central("accuracy", 3.0, 8.0);
    let counters = Counters { users_trained: 1, steps: 20, ..Default::default() };
    RoundResult {
        worker: 3,
        round: 41,
        seq: 1337,
        partial: Some(partial),
        metrics,
        counters,
        costs: vec![UserCost { datapoints: 50, nanos: 1_000_000, device_nanos: 600_000 }],
        error: None,
    }
}

fn main() -> anyhow::Result<()> {
    let mut records = Vec::new();
    let result = sample_result(DIM);
    let ctx = CentralContext::train(41, 16, LocalParams::default(), 7);
    let central: Vec<f32> = (0..DIM).map(|i| (i % 97) as f32 * 1e-3).collect();

    // ---- result encode/decode (the per-user upload) -----------------
    let mut buf = Vec::new();
    encode_round_result(&mut buf, &result);
    let result_bytes = buf.len();
    println!("result payload: {:.2} MB at d={DIM}", result_bytes as f64 / 1e6);

    let (r, alloc) = bench_per_op_alloc("encode/round-result", 2, 10, 4, || {
        for _ in 0..4 {
            buf.clear();
            encode_round_result(&mut buf, &result);
            black_box(buf.len());
        }
    });
    records.push(BenchRecord::new(&r, alloc));

    let (r, alloc) = bench_per_op_alloc("decode/round-result", 2, 10, 4, || {
        for _ in 0..4 {
            let mut cur = Cursor::new(&buf);
            let back = decode_round_result(&mut cur).unwrap();
            black_box(back.seq);
        }
    });
    records.push(BenchRecord::new(&r, alloc));

    // ---- round command encode/decode (the per-user download) --------
    let mut cmd_buf = Vec::new();
    encode_round(&mut cmd_buf, 1337, &ctx, &central, &[41]);
    println!("round payload:  {:.2} MB at d={DIM}", cmd_buf.len() as f64 / 1e6);

    let (r, alloc) = bench_per_op_alloc("encode/round-cmd", 2, 10, 4, || {
        for _ in 0..4 {
            cmd_buf.clear();
            encode_round(&mut cmd_buf, 1337, &ctx, &central, &[41]);
            black_box(cmd_buf.len());
        }
    });
    records.push(BenchRecord::new(&r, alloc));

    let (r, alloc) = bench_per_op_alloc("decode/round-cmd", 2, 10, 4, || {
        for _ in 0..4 {
            let mut cur = Cursor::new(&cmd_buf);
            let back = decode_round(&mut cur).unwrap();
            black_box(back.seq);
        }
    });
    records.push(BenchRecord::new(&r, alloc));

    // ---- framed round-trip: socketpair vs the mpsc channel ----------
    // echo peer: read a frame, write it straight back
    let (mut here, mut there) = UnixStream::pair()?;
    let echo = std::thread::spawn(move || {
        while let Ok((tag, payload, _)) = read_frame(&mut there) {
            if tag == FRAME_ROUND {
                break;
            }
            if write_frame(&mut there, tag, &payload).is_err() {
                break;
            }
        }
    });
    let (r, alloc) = bench_per_op_alloc("roundtrip/socketpair", 2, 10, 2, || {
        for _ in 0..2 {
            write_frame(&mut here, FRAME_RESULT, &buf).unwrap();
            let (_, back, _) = read_frame(&mut here).unwrap();
            black_box(back.len());
        }
    });
    records.push(BenchRecord::new(&r, alloc));
    write_frame(&mut here, FRAME_ROUND, &[]).unwrap(); // stop the echo peer
    echo.join().unwrap();

    // baseline: the same payload bytes through an in-process channel
    // pair (what the threaded WorkerPool pays instead of the socket)
    let (tx, rx) = std::sync::mpsc::channel::<Vec<u8>>();
    let (tx2, rx2) = std::sync::mpsc::channel::<Vec<u8>>();
    let pong = std::thread::spawn(move || {
        while let Ok(v) = rx.recv() {
            if v.is_empty() || tx2.send(v).is_err() {
                break;
            }
        }
    });
    let (r, alloc) = bench_per_op_alloc("roundtrip/mpsc-channel", 2, 10, 2, || {
        for _ in 0..2 {
            tx.send(buf.clone()).unwrap();
            black_box(rx2.recv().unwrap().len());
        }
    });
    records.push(BenchRecord::new(&r, alloc));
    tx.send(Vec::new()).unwrap();
    pong.join().unwrap();

    records.push(BenchRecord {
        name: "bytes/round-result".into(),
        ns_per_op: result_bytes as f64,
        alloc_bytes_per_op: 0.0,
    });
    write_bench_json("BENCH_comms.json", &records)?;
    println!("wrote BENCH_comms.json ({} records)", records.len());
    Ok(())
}
