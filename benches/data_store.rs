//! Bench: the out-of-core sharded dataset store (ISSUE 5) — read
//! latency per user in the three regimes that matter for the cohort
//! pipeline, plus the zero-allocation invariant of the cache hit path.
//!
//! Emits `BENCH_data.json`:
//! * `data_store/cold/ns_per_user` — cache empty, no prefetch: every
//!   fetch pays the shard read (the regime the prefetcher exists to
//!   hide).
//! * `data_store/warm/ns_per_user` — 100% cache-hit rate; the in-bench
//!   assert requires **zero** heap allocation per fetch in this regime
//!   (`alloc_bytes_per_op == 0`, counted by the global allocator).
//! * `data_store/prefetched/stall_ns_per_user` vs
//!   `data_store/unprefetched/stall_ns_per_user` — time the "training"
//!   loop was blocked on disk with and without the dispatcher-fed
//!   prefetch thread running ahead; prefetching must stall strictly
//!   less (asserted when the unprefetched baseline stalls at all).
//! * `data_store/mmap_read/alloc_bytes_per_user` vs
//!   `data_store/pread_read/alloc_bytes_per_user` — the warm-mmap read
//!   path is zero-copy beyond `UserData` assembly: its per-read heap
//!   allocation equals the decoded payload exactly (asserted), while
//!   pread additionally allocates the staging blob buffer (asserted
//!   strictly larger).
//! * `data_store/compressed_cold|compressed_warm/ns_per_user` and
//!   `data_store/compressed/disk_frac` — shuffle-lz rows on synthetic
//!   text (asserted ≤ 0.6× raw on-disk), with worker-side decode nanos
//!   asserted 0 whenever the prefetcher won every race.

use std::sync::Arc;
use std::time::Instant;

use pfl::data::{
    materialize, materialize_with, Compression, FederatedDataset, OpenOptions, ShardedStore,
    SourceConfig, StoreSource, SynthCifar, SynthText, UserData, UserDataSource,
};
use pfl::util::bench::{
    bench_per_op, bench_per_op_alloc, write_bench_json, BenchRecord, CountingAlloc,
};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const USERS: usize = 96;
const PER_USER: usize = 10;
/// Simulated local-training time per user in the prefetch-overlap
/// measurement; the prefetcher has this long to load the next users.
const TRAIN_NS: u64 = 300_000;

fn spin_ns(ns: u64) {
    let start = Instant::now();
    while (start.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

/// Fetch every user once in order, spinning `train_ns` after each (the
/// local-training phase prefetch overlaps with); returns total stall ns.
fn consume_round(src: &StoreSource, order: &[usize], train_ns: u64) -> u64 {
    let mut stall = 0;
    for &uid in order {
        let f = src.fetch(uid);
        stall += f.stall_nanos;
        std::hint::black_box(&f.data);
        if train_ns > 0 {
            spin_ns(train_ns);
        }
    }
    stall
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("pfl_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // CIFAR-shaped users (~123 KB each): big enough that a read is real
    // work, small enough that the bench stays quick
    let gen = SynthCifar::new(USERS, PER_USER, None, 7);
    let stats = materialize(&gen, &dir, 16, 0)?;
    println!(
        "materialized {} users, {} shards, {:.1} MB",
        stats.num_users,
        stats.num_shards,
        stats.data_bytes as f64 / 1e6
    );
    let store = Arc::new(ShardedStore::open(&dir)?);
    let order: Vec<usize> = (0..USERS).collect();

    // --- cold: empty cache, no prefetch thread ----------------------
    // a fresh source per iteration so no fetch ever hits
    let cold = bench_per_op("data_store/cold", 1, 5, USERS, || {
        let src = StoreSource::new(
            store.clone(),
            SourceConfig { cache_users: USERS, prefetch_depth: 0 },
        );
        let stall = consume_round(&src, &order, 0);
        std::hint::black_box(stall);
    });

    // --- warm: 100% hit rate, zero allocation per fetch -------------
    let warm_src = StoreSource::new(
        store.clone(),
        SourceConfig { cache_users: USERS, prefetch_depth: 0 },
    );
    consume_round(&warm_src, &order, 0); // fill the cache
    let (warm, warm_alloc) = bench_per_op_alloc("data_store/warm", 2, 9, USERS, || {
        for &uid in &order {
            let f = warm_src.fetch(uid);
            assert_eq!(f.cache_hit, Some(true), "warm fetch missed");
            std::hint::black_box(&f.data);
        }
    });
    assert_eq!(
        warm_alloc, 0.0,
        "cache hits must not allocate: {warm_alloc} bytes/op at 100% hit rate"
    );

    // --- prefetched vs not: stall while "training" overlaps ---------
    // small cache so nothing survives between measurements; the
    // prefetcher gets the dispatch order up front, stays `depth` users
    // ahead, and the training spin gives it time to win the race
    let measure_stall = |depth: usize| -> u64 {
        let src = StoreSource::new(
            store.clone(),
            SourceConfig { cache_users: 16, prefetch_depth: depth },
        );
        if depth > 0 {
            src.hint_round(&order);
        }
        consume_round(&src, &order, TRAIN_NS) / USERS as u64
    };
    let unprefetched_stall = measure_stall(0);
    let prefetched_stall = measure_stall(8);
    println!(
        "stall/user: unprefetched {:>8} ns, prefetched {:>8} ns",
        unprefetched_stall, prefetched_stall
    );
    if unprefetched_stall > 0 {
        assert!(
            prefetched_stall < unprefetched_stall,
            "prefetch did not reduce stalls: {prefetched_stall} >= {unprefetched_stall} ns/user"
        );
    }

    // --- mmap zero-copy: a warm read allocates only the UserData ----
    // expected per-read allocation = the decoded payload vectors, which
    // `decode_user_data` sizes exactly (collect from an exact-size
    // iterator); the mmap path decodes straight from the mapping, the
    // pread path additionally allocates the staging blob buffer
    let expected_payload: f64 = (0..USERS)
        .map(|u| match gen.user_data(u) {
            UserData::Image { x, y, .. } => 4.0 * (x.len() + y.len()) as f64,
            _ => unreachable!("SynthCifar yields Image data"),
        })
        .sum::<f64>()
        / USERS as f64;
    let mmap_store = ShardedStore::open_with(&dir, OpenOptions { mmap: true })?;
    let pread_store = ShardedStore::open_with(&dir, OpenOptions { mmap: false })?;
    for uid in 0..USERS {
        // warm the file-handle map, the mapping, and the page cache
        std::hint::black_box(mmap_store.read_user(uid)?);
        std::hint::black_box(pread_store.read_user(uid)?);
    }
    let (_, mmap_alloc) = bench_per_op_alloc("data_store/mmap_read", 1, 5, USERS, || {
        for &uid in &order {
            std::hint::black_box(mmap_store.read_user(uid).unwrap());
        }
    });
    let (_, pread_alloc) = bench_per_op_alloc("data_store/pread_read", 1, 5, USERS, || {
        for &uid in &order {
            std::hint::black_box(pread_store.read_user(uid).unwrap());
        }
    });
    println!(
        "alloc/read: mmap {mmap_alloc:.0} B (payload {expected_payload:.0} B), \
         pread {pread_alloc:.0} B"
    );
    if mmap_store.uses_mmap() {
        assert!(
            (mmap_alloc - expected_payload).abs() < 1.0,
            "mmap read path must be zero-copy beyond UserData assembly: \
             {mmap_alloc} B/read vs {expected_payload} B payload"
        );
        assert!(
            pread_alloc > mmap_alloc,
            "pread must pay the staging copy: {pread_alloc} <= {mmap_alloc} B/read"
        );
    }

    // --- compressed vs raw: synthetic text, shuffle-lz --------------
    let text = SynthText::new(USERS, 23);
    let raw_dir = std::env::temp_dir().join(format!("pfl_bench_traw_{}", std::process::id()));
    let lz_dir = std::env::temp_dir().join(format!("pfl_bench_tlz_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&raw_dir);
    let _ = std::fs::remove_dir_all(&lz_dir);
    let raw_stats = materialize(&text, &raw_dir, 16, 0)?;
    let lz_stats = materialize_with(&text, &lz_dir, 16, 0, Compression::ShuffleLz)?;
    let disk_frac = lz_stats.disk_bytes as f64 / raw_stats.disk_bytes as f64;
    println!(
        "synth text on disk: raw {:.1} KB, shuffle-lz {:.1} KB ({:.2}x)",
        raw_stats.disk_bytes as f64 / 1e3,
        lz_stats.disk_bytes as f64 / 1e3,
        disk_frac
    );
    assert!(
        disk_frac <= 0.6,
        "shuffle-lz must reach <= 0.6x raw on synthetic text, got {disk_frac:.2}x"
    );
    let lz_store = Arc::new(ShardedStore::open(&lz_dir)?);
    let comp_cold = bench_per_op("data_store/compressed_cold", 1, 5, USERS, || {
        let src = StoreSource::new(
            lz_store.clone(),
            SourceConfig { cache_users: USERS, prefetch_depth: 0 },
        );
        let stall = consume_round(&src, &order, 0);
        std::hint::black_box(stall);
    });
    let comp_warm_src = StoreSource::new(
        lz_store.clone(),
        SourceConfig { cache_users: USERS, prefetch_depth: 0 },
    );
    consume_round(&comp_warm_src, &order, 0); // fill the cache
    let comp_warm = bench_per_op("data_store/compressed_warm", 1, 5, USERS, || {
        for &uid in &order {
            std::hint::black_box(&comp_warm_src.fetch(uid).data);
        }
    });

    // --- decode off the critical path -------------------------------
    // a cold worker-side read pays the block decode; with the prefetch
    // thread ahead, every cache hit reports decode_nanos == 0 by
    // construction — assert it whenever the prefetcher won every race
    let cold_src = StoreSource::new(
        lz_store.clone(),
        SourceConfig { cache_users: USERS, prefetch_depth: 0 },
    );
    let cold_decode: u64 = order.iter().map(|&uid| cold_src.fetch(uid).decode_nanos).sum();
    assert!(cold_decode > 0, "cold compressed reads must decode on the worker");
    let pf_src = StoreSource::new(
        lz_store.clone(),
        SourceConfig { cache_users: 16, prefetch_depth: 8 },
    );
    pf_src.hint_round(&order);
    let mut pf_decode = 0u64;
    let mut pf_hits = 0usize;
    for &uid in &order {
        let f = pf_src.fetch(uid);
        pf_decode += f.decode_nanos;
        pf_hits += (f.cache_hit == Some(true)) as usize;
        spin_ns(TRAIN_NS);
    }
    println!(
        "worker decode/round: cold {} ns, prefetched {} ns ({} / {} hits)",
        cold_decode,
        pf_decode,
        pf_hits,
        order.len()
    );
    if pf_hits == order.len() {
        assert_eq!(
            pf_decode, 0,
            "prefetched fetches must not decode on the worker thread"
        );
    }

    write_bench_json(
        "BENCH_data.json",
        &[
            BenchRecord {
                name: "data_store/cold/ns_per_user".into(),
                ns_per_op: cold.median.as_nanos() as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "data_store/warm/ns_per_user".into(),
                ns_per_op: warm.median.as_nanos() as f64,
                alloc_bytes_per_op: warm_alloc,
            },
            BenchRecord {
                name: "data_store/unprefetched/stall_ns_per_user".into(),
                ns_per_op: unprefetched_stall as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "data_store/prefetched/stall_ns_per_user".into(),
                ns_per_op: prefetched_stall as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "data_store/mmap_read/ns_per_user".into(),
                ns_per_op: 0.0,
                alloc_bytes_per_op: mmap_alloc,
            },
            BenchRecord {
                name: "data_store/pread_read/ns_per_user".into(),
                ns_per_op: 0.0,
                alloc_bytes_per_op: pread_alloc,
            },
            BenchRecord {
                name: "data_store/compressed_cold/ns_per_user".into(),
                ns_per_op: comp_cold.median.as_nanos() as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "data_store/compressed_warm/ns_per_user".into(),
                ns_per_op: comp_warm.median.as_nanos() as f64,
                alloc_bytes_per_op: 0.0,
            },
            // disk_frac is a ratio, not a latency; the json schema only
            // carries ns_per_op so it rides in that slot
            BenchRecord {
                name: "data_store/compressed/disk_frac".into(),
                ns_per_op: disk_frac,
                alloc_bytes_per_op: 0.0,
            },
        ],
    )?;
    println!("wrote BENCH_data.json");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&raw_dir);
    let _ = std::fs::remove_dir_all(&lz_dir);
    Ok(())
}
