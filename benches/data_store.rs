//! Bench: the out-of-core sharded dataset store (ISSUE 5) — read
//! latency per user in the three regimes that matter for the cohort
//! pipeline, plus the zero-allocation invariant of the cache hit path.
//!
//! Emits `BENCH_data.json`:
//! * `data_store/cold/ns_per_user` — cache empty, no prefetch: every
//!   fetch pays the shard read (the regime the prefetcher exists to
//!   hide).
//! * `data_store/warm/ns_per_user` — 100% cache-hit rate; the in-bench
//!   assert requires **zero** heap allocation per fetch in this regime
//!   (`alloc_bytes_per_op == 0`, counted by the global allocator).
//! * `data_store/prefetched/stall_ns_per_user` vs
//!   `data_store/unprefetched/stall_ns_per_user` — time the "training"
//!   loop was blocked on disk with and without the dispatcher-fed
//!   prefetch thread running ahead; prefetching must stall strictly
//!   less (asserted when the unprefetched baseline stalls at all).

use std::sync::Arc;
use std::time::Instant;

use pfl::data::{
    materialize, ShardedStore, SourceConfig, StoreSource, SynthCifar, UserDataSource,
};
use pfl::util::bench::{
    bench_per_op, bench_per_op_alloc, write_bench_json, BenchRecord, CountingAlloc,
};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const USERS: usize = 96;
const PER_USER: usize = 10;
/// Simulated local-training time per user in the prefetch-overlap
/// measurement; the prefetcher has this long to load the next users.
const TRAIN_NS: u64 = 300_000;

fn spin_ns(ns: u64) {
    let start = Instant::now();
    while (start.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

/// Fetch every user once in order, spinning `train_ns` after each (the
/// local-training phase prefetch overlaps with); returns total stall ns.
fn consume_round(src: &StoreSource, order: &[usize], train_ns: u64) -> u64 {
    let mut stall = 0;
    for &uid in order {
        let f = src.fetch(uid);
        stall += f.stall_nanos;
        std::hint::black_box(&f.data);
        if train_ns > 0 {
            spin_ns(train_ns);
        }
    }
    stall
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("pfl_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // CIFAR-shaped users (~123 KB each): big enough that a read is real
    // work, small enough that the bench stays quick
    let gen = SynthCifar::new(USERS, PER_USER, None, 7);
    let stats = materialize(&gen, &dir, 16, 0)?;
    println!(
        "materialized {} users, {} shards, {:.1} MB",
        stats.num_users,
        stats.num_shards,
        stats.data_bytes as f64 / 1e6
    );
    let store = Arc::new(ShardedStore::open(&dir)?);
    let order: Vec<usize> = (0..USERS).collect();

    // --- cold: empty cache, no prefetch thread ----------------------
    // a fresh source per iteration so no fetch ever hits
    let cold = bench_per_op("data_store/cold", 1, 5, USERS, || {
        let src = StoreSource::new(
            store.clone(),
            SourceConfig { cache_users: USERS, prefetch_depth: 0 },
        );
        let stall = consume_round(&src, &order, 0);
        std::hint::black_box(stall);
    });

    // --- warm: 100% hit rate, zero allocation per fetch -------------
    let warm_src = StoreSource::new(
        store.clone(),
        SourceConfig { cache_users: USERS, prefetch_depth: 0 },
    );
    consume_round(&warm_src, &order, 0); // fill the cache
    let (warm, warm_alloc) = bench_per_op_alloc("data_store/warm", 2, 9, USERS, || {
        for &uid in &order {
            let f = warm_src.fetch(uid);
            assert_eq!(f.cache_hit, Some(true), "warm fetch missed");
            std::hint::black_box(&f.data);
        }
    });
    assert_eq!(
        warm_alloc, 0.0,
        "cache hits must not allocate: {warm_alloc} bytes/op at 100% hit rate"
    );

    // --- prefetched vs not: stall while "training" overlaps ---------
    // small cache so nothing survives between measurements; the
    // prefetcher gets the dispatch order up front, stays `depth` users
    // ahead, and the training spin gives it time to win the race
    let measure_stall = |depth: usize| -> u64 {
        let src = StoreSource::new(
            store.clone(),
            SourceConfig { cache_users: 16, prefetch_depth: depth },
        );
        if depth > 0 {
            src.hint_round(&order);
        }
        consume_round(&src, &order, TRAIN_NS) / USERS as u64
    };
    let unprefetched_stall = measure_stall(0);
    let prefetched_stall = measure_stall(8);
    println!(
        "stall/user: unprefetched {:>8} ns, prefetched {:>8} ns",
        unprefetched_stall, prefetched_stall
    );
    if unprefetched_stall > 0 {
        assert!(
            prefetched_stall < unprefetched_stall,
            "prefetch did not reduce stalls: {prefetched_stall} >= {unprefetched_stall} ns/user"
        );
    }

    write_bench_json(
        "BENCH_data.json",
        &[
            BenchRecord {
                name: "data_store/cold/ns_per_user".into(),
                ns_per_op: cold.median.as_nanos() as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "data_store/warm/ns_per_user".into(),
                ns_per_op: warm.median.as_nanos() as f64,
                alloc_bytes_per_op: warm_alloc,
            },
            BenchRecord {
                name: "data_store/unprefetched/stall_ns_per_user".into(),
                ns_per_op: unprefetched_stall as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "data_store/prefetched/stall_ns_per_user".into(),
                ns_per_op: prefetched_stall as f64,
                alloc_bytes_per_op: 0.0,
            },
        ],
    )?;
    println!("wrote BENCH_data.json");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
