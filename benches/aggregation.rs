//! Bench: the aggregation hot path — per-user accumulate (runs cohort
//! times per round) and the worker reduce (once per round), at the
//! benchmark models' parameter counts. Paper §3 item 4: tensors stay in
//! one buffer end-to-end; this is the Rust analogue (add_assign into the
//! resident accumulator, no reallocation).

use pfl::fl::aggregator::{Aggregator, SumAggregator};
use pfl::fl::stats::Statistics;
use pfl::util::bench::{bench, bench_per_op, black_box};

fn main() {
    for &d in &[119_569usize, 545_098, 1_964_640] {
        let agg = SumAggregator;
        let users = 10;
        bench_per_op(&format!("accumulate/user d={d}"), 2, 10, users, || {
            let mut acc: Option<Statistics> = None;
            for u in 0..users {
                agg.accumulate(
                    &mut acc,
                    Statistics::new_update(vec![u as f32 * 1e-3; d], 1.0),
                );
            }
            black_box(acc.map(|a| a.weight));
        });
        bench(&format!("worker_reduce/8 partials d={d}"), 2, 10, || {
            let partials: Vec<Statistics> =
                (0..8).map(|w| Statistics::new_update(vec![w as f32; d], 6.0)).collect();
            black_box(agg.worker_reduce(partials).map(|a| a.weight));
        });
        bench(&format!("average_in_place d={d}"), 2, 10, || {
            let mut s = Statistics::new_update(vec![1.0; d], 50.0);
            s.average_in_place();
            black_box(s.weight);
        });
    }
}
