//! Bench: the aggregation hot path — per-user accumulate (runs cohort
//! times per round) and the worker reduce (once per round), at the
//! benchmark models' parameter counts. Paper §3 item 4: tensors stay in
//! one buffer end-to-end.
//!
//! Two accumulate variants are measured per dimension:
//!
//! * `accumulate/moved` — the pre-arena protocol: materialize one
//!   `Statistics` per user (the aggregator takes ownership) and fold it
//!   into an `Option<Statistics>` accumulator. Allocates one model-sized
//!   vector per user.
//! * `accumulate/arena` — the worker hot path since the tensor layer:
//!   fold the user's statistics **by reference** into the resident
//!   `StatsArena` buffers. Zero allocation per user in steady state.
//!
//! Results (ns/op + heap bytes/op, measured through `CountingAlloc`) are
//! written to `BENCH_aggregation.json` so the perf trajectory is tracked
//! across PRs.

use pfl::fl::aggregator::{tree_reduce, Aggregator, SumAggregator};
use pfl::fl::stats::{StatValue, Statistics};
use pfl::tensor::StatsArena;
use pfl::util::bench::{
    bench_per_op_alloc, black_box, write_bench_json, BenchRecord, CountingAlloc,
};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Benchmark model parameter counts (mlp_flair / cnn_c10 / lm_so).
const DIMS: [usize; 3] = [119_569, 545_098, 1_964_640];

fn main() -> anyhow::Result<()> {
    let mut records = Vec::new();
    for &d in &DIMS {
        let agg = SumAggregator;
        let users = 10;

        // pre-arena protocol: one model-sized Vec materialized + moved
        // per user (accumulate consumes its argument)
        let (r, alloc) =
            bench_per_op_alloc(&format!("accumulate/moved d={d}"), 2, 10, users, || {
                let mut acc: Option<Statistics> = None;
                for u in 0..users {
                    agg.accumulate(
                        &mut acc,
                        Statistics::new_update(vec![u as f32 * 1e-3; d], 1.0),
                    );
                }
                black_box(acc.map(|a| a.weight));
            });
        records.push(BenchRecord::new(&r, alloc));

        // arena hot path: the user's statistics live in the model's
        // resident buffer; the fold borrows them
        let user = Statistics::new_update(vec![1e-3f32; d], 1.0);
        let mut arena = StatsArena::new();
        arena.fold(&user); // size the slots outside the timer
        arena.take_partial();
        let mut steady_grown = 0u64;
        let (r, alloc) =
            bench_per_op_alloc(&format!("accumulate/arena d={d}"), 2, 10, users, || {
                for _ in 0..users {
                    arena.fold(&user);
                }
                black_box(arena.weight());
                // capture growth before reset clears the bookkeeping
                steady_grown += arena.drain_grown_bytes();
                arena.reset();
            });
        records.push(BenchRecord::new(&r, alloc));
        assert_eq!(steady_grown, 0, "steady-state arena fold must not allocate");

        // sparse arena path (GBDT-style tiny users): 64-nnz updates of a
        // d-dim model stay in the slot's sorted sparse accumulator — no
        // model-sized buffer is ever allocated in the loop
        let nnz = 64usize;
        let sparse_users: Vec<Statistics> = (0..users)
            .map(|u| {
                let mut idx: Vec<u32> =
                    (0..nnz).map(|i| ((i * (d / nnz) + u) % d) as u32).collect();
                idx.sort_unstable();
                idx.dedup();
                let val = vec![1e-3f32; idx.len()];
                Statistics::new_update_value(StatValue::sparse(d as u32, idx, val), 1.0)
            })
            .collect();
        let mut sarena = StatsArena::new();
        for u in &sparse_users {
            sarena.fold(u); // size the ping-pong buffers outside the timer
        }
        sarena.drain_grown_bytes();
        sarena.take_partial();
        let mut sparse_grown = 0u64;
        let (r, alloc) =
            bench_per_op_alloc(&format!("accumulate/sparse-arena d={d}"), 2, 10, users, || {
                for u in &sparse_users {
                    sarena.fold(u);
                }
                black_box(sarena.weight());
                sparse_grown += sarena.drain_grown_bytes();
                sarena.reset();
            });
        records.push(BenchRecord::new(&r, alloc));
        assert_eq!(sparse_grown, 0, "steady-state sparse fold must not allocate");
        assert_eq!(sarena.drain_spill_count(), 0, "all-sparse cohort must not spill");

        let (r, alloc) =
            bench_per_op_alloc(&format!("worker_reduce/8 partials d={d}"), 2, 10, 1, || {
                let partials: Vec<Statistics> =
                    (0..8).map(|w| Statistics::new_update(vec![w as f32; d], 6.0)).collect();
                black_box(agg.worker_reduce(partials).map(|a| a.weight));
            });
        records.push(BenchRecord::new(&r, alloc));

        let (r, alloc) =
            bench_per_op_alloc(&format!("average_in_place d={d}"), 2, 10, 1, || {
                let mut s = Statistics::new_update(vec![1.0; d], 50.0);
                s.average_in_place();
                black_box(s.weight);
            });
        records.push(BenchRecord::new(&r, alloc));
    }

    // serial left fold vs parallel tree fold over worker partials (the
    // once-per-round reduce). The tree pairs adjacent partials per level
    // (depth ceil(log2 n)) and merges pairs on scoped threads; it folds
    // the same pairs as the chain in a different association, so beyond
    // per-merge thread bookkeeping it must not cost extra heap.
    {
        let d = DIMS[1];
        let agg = SumAggregator;
        let dense_partials = |n: usize| -> Vec<Statistics> {
            (0..n).map(|w| Statistics::new_update(vec![w as f32 * 1e-3; d], 6.0)).collect()
        };
        let nnz = 4096usize;
        let sparse_partials = |n: usize| -> Vec<Statistics> {
            (0..n)
                .map(|w| {
                    let mut idx: Vec<u32> =
                        (0..nnz).map(|i| ((i * (d / nnz) + w) % d) as u32).collect();
                    idx.sort_unstable();
                    idx.dedup();
                    let val = vec![1e-3f32; idx.len()];
                    Statistics::new_update_value(StatValue::sparse(d as u32, idx, val), 6.0)
                })
                .collect()
        };
        for &n in &[4usize, 8, 16] {
            for shape in ["dense", "sparse"] {
                let make: &dyn Fn(usize) -> Vec<Statistics> =
                    if shape == "dense" { &dense_partials } else { &sparse_partials };
                let (r, serial_alloc) =
                    bench_per_op_alloc(&format!("fold/serial n={n} {shape} d={d}"), 2, 10, 1, || {
                        black_box(agg.worker_reduce(make(n)).map(|a| a.weight));
                    });
                records.push(BenchRecord::new(&r, serial_alloc));

                let (r, tree_alloc) =
                    bench_per_op_alloc(&format!("fold/tree n={n} {shape} d={d}"), 2, 10, 1, || {
                        let (acc, depth) = tree_reduce(&agg, make(n));
                        black_box(acc.map(|a| a.weight));
                        black_box(depth);
                    });
                records.push(BenchRecord::new(&r, tree_alloc));

                // thread-spawn bookkeeping is the only tree-side extra;
                // the model-sized buffers dominate both rows
                assert!(
                    tree_alloc <= serial_alloc + 64.0 * 1024.0,
                    "tree fold allocates more than serial: {tree_alloc} vs {serial_alloc} \
                     bytes/op (n={n} {shape})"
                );
            }
        }
    }

    // headline ratio for the dense accumulate path
    for d in DIMS {
        let moved = records.iter().find(|r| r.name == format!("accumulate/moved d={d}"));
        let arena = records.iter().find(|r| r.name == format!("accumulate/arena d={d}"));
        if let (Some(m), Some(a)) = (moved, arena) {
            println!(
                "d={d}: arena speedup {:.2}x (alloc {:.0} -> {:.0} bytes/op)",
                m.ns_per_op / a.ns_per_op.max(1.0),
                m.alloc_bytes_per_op,
                a.alloc_bytes_per_op
            );
        }
    }

    write_bench_json("BENCH_aggregation.json", &records)?;
    println!("wrote BENCH_aggregation.json");
    Ok(())
}
