//! Bench: one full central iteration, end to end (sample → schedule →
//! local training on worker replicas → postprocess → reduce → DP noise →
//! central update). The ratio between this and `runtime_hotpath`'s raw
//! step time is the framework overhead — the quantity pfl-research's
//! design minimizes (paper §3; its analogue of Table 1's pfl rows).

use pfl::baselines::EngineVariant;
use pfl::config::build;
use pfl::fl::callbacks::Callback;
use pfl::util::bench::bench;

fn main() -> anyhow::Result<()> {
    if pfl::runtime::Manifest::load_default().is_err() {
        eprintln!("skipping end_to_end_round: run `make artifacts` first");
        return Ok(());
    }

    for (label, preset, dp) in [
        ("cifar10 C=10", "cifar10-iid", false),
        ("cifar10 C=10 +DP", "cifar10-iid-dp", true),
    ] {
        let mut cfg = pfl::config::preset(preset)?;
        cfg.iterations = 1; // measured per-round via repeated runs below
        cfg.cohort_size = 10;
        cfg.dataset.num_users = 100;
        cfg.eval_every = 10_000;
        if dp {
            cfg.privacy.noise_cohort = 200.0;
        }

        // persistent backend: compile once, then time rounds
        let mut backend = build::build_backend(&cfg, EngineVariant::PflStyle.profile())?;
        let init = build::init_params(&cfg)?;
        // warm-up round compiles the executables
        let _ = backend.run(init.clone(), &mut Vec::<Box<dyn Callback>>::new())?;
        drop(backend);

        // measure full (build + 3 rounds) minus build amortization by
        // timing a 3-round run with a pre-warmed artifact cache per
        // iteration; PJRT compilation is part of round 0 only.
        let mut cfg3 = cfg.clone();
        cfg3.iterations = 3;
        bench(&format!("round/{label} (3 rounds incl. setup)"), 0, 3, || {
            let mut b = build::build_backend(&cfg3, EngineVariant::PflStyle.profile()).unwrap();
            let out = b.run(init.clone(), &mut Vec::<Box<dyn Callback>>::new()).unwrap();
            pfl::util::bench::black_box(out.rounds);
        });

        // round-only timing from the outcome's own per-round clock
        let mut cfg10 = cfg.clone();
        cfg10.iterations = 8;
        let mut b = build::build_backend(&cfg10, EngineVariant::PflStyle.profile())?;
        let out = b.run(init.clone(), &mut Vec::<Box<dyn Callback>>::new())?;
        let warm: Vec<f64> =
            out.round_nanos.iter().skip(1).map(|n| *n as f64 / 1e9).collect();
        let mean = warm.iter().sum::<f64>() / warm.len() as f64;
        let busy: u64 = out.worker_busy_nanos.iter().sum();
        println!(
            "round/{label}: warm rounds mean {mean:.3}s over {} rounds; device-busy frac {:.2}",
            warm.len(),
            (busy as f64 / 1e9) / out.wall_secs
        );
    }
    Ok(())
}
