//! Bench: the greedy user-scheduler (paper App. B.6). It runs once per
//! (context, cohort), so it must stay negligible next to local training —
//! the perf target is < 1 ms at cohort 50k (the paper's largest, Fig. 3
//! right).

use pfl::fl::scheduler::{median, schedule, SchedulerKind};
use pfl::util::bench::{bench, black_box};
use pfl::util::rng::Rng;

fn weights(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| rng.lognormal(2.5, 1.2).ceil().max(1.0)).collect()
}

fn main() {
    println!("# scheduler cost per cohort (workers = 32)");
    for n in [50usize, 400, 5_000, 50_000] {
        let w = weights(n, 7);
        for kind in [
            SchedulerKind::Uniform,
            SchedulerKind::Greedy,
            SchedulerKind::GreedyBase { base: median(&w) },
            SchedulerKind::GreedyMedianBase,
        ] {
            bench(&format!("schedule/{kind:?}/cohort={n}"), 2, 10, || {
                black_box(schedule(kind, &w, 32));
            });
        }
    }
    println!("# straggler-gap quality at cohort 5000 (lower is better)");
    let w = weights(5_000, 3);
    for kind in [SchedulerKind::Uniform, SchedulerKind::Greedy, SchedulerKind::GreedyMedianBase] {
        let gap = schedule(kind, &w, 32).predicted_straggler_gap();
        println!("{kind:?}: predicted straggler gap = {gap:.1} weight units");
    }
}
