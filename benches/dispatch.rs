//! Bench: the dispatch engines (ISSUE 3) on a heavy-tailed synthetic
//! cohort — log-normal user sizes, 4 workers, a model whose per-user
//! cost is proportional to its datapoints (busy-wait emulated, so the
//! measured gap is deterministic up to OS jitter).
//!
//! Emits `BENCH_dispatch.json`:
//! * `dispatch/{static,worksteal}/straggler_ns` — measured per-round
//!   straggler gap (max − min worker busy). WorkStealing must report a
//!   strictly smaller gap than Static on this workload.
//! * `dispatch/worksteal/steals` — users migrated off stragglers.
//! * `dispatch/async/{rounds,wall_ns}` — the async engine completes its
//!   round budget with no all-worker barrier (round count independent of
//!   the slowest worker).

use std::sync::Arc;
use std::time::Instant;

use pfl::baselines::OverheadProfile;
use pfl::data::{FederatedDataset, GeneratorSource, UserData};
use pfl::fl::algorithm::RunSpec;
use pfl::fl::backend::{BackendBuilder, RunParams};
use pfl::fl::central_opt::Sgd;
use pfl::fl::context::{CentralContext, DispatchSpec, LocalParams};
use pfl::fl::dispatch::{steal_count, Dispatcher, StaticDispatcher, WorkStealingDispatcher};
use pfl::fl::model::{ScoreSink, TrainOutput};
use pfl::fl::worker::{WorkerPool, WorkerShared};
use pfl::fl::{FedAvg, Metrics, Model, SchedulerKind, SumAggregator};
use pfl::simsys::straggler_gap_nanos;
use pfl::util::bench::{write_bench_json, BenchRecord, CountingAlloc};
use pfl::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const DIM: usize = 4;
const WORKERS: usize = 4;
/// Busy-wait per datapoint: a median (~e^3 ≈ 20 point) user costs ~1 ms.
const NS_PER_POINT: u64 = 50_000;

/// Log-normal user sizes (FLAIR-like dispersion), data itself is dummy.
struct LogNormalUsers {
    sizes: Vec<usize>,
}

impl LogNormalUsers {
    fn new(users: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        LogNormalUsers {
            sizes: (0..users).map(|_| rng.lognormal(3.0, 1.2).ceil().max(1.0) as usize).collect(),
        }
    }
}

impl FederatedDataset for LogNormalUsers {
    fn name(&self) -> &str {
        "lognormal-spin"
    }
    fn num_users(&self) -> usize {
        self.sizes.len()
    }
    fn user_data(&self, uid: usize) -> UserData {
        UserData::Points { x: vec![0.0; self.sizes[uid] * DIM], dim: DIM }
    }
    fn user_len(&self, uid: usize) -> usize {
        self.sizes[uid]
    }
    fn central_eval(&self, _shard_size: usize) -> Vec<UserData> {
        Vec::new()
    }
}

/// A model whose local training cost is `datapoints × NS_PER_POINT`
/// (busy-wait, like the baseline overhead emulation in `worker.rs`).
struct SpinModel {
    central: Vec<f32>,
}

fn spin_ns(ns: u64) {
    let start = Instant::now();
    while (start.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

impl Model for SpinModel {
    fn param_count(&self) -> usize {
        self.central.len()
    }
    fn set_central(&mut self, central: &[f32]) {
        self.central.copy_from_slice(central);
    }
    fn central(&self) -> &[f32] {
        &self.central
    }
    fn train_local(
        &mut self,
        data: &UserData,
        _p: &LocalParams,
        _c_diff: Option<&[f32]>,
        _seed: u64,
    ) -> anyhow::Result<TrainOutput> {
        let n = data.len();
        spin_ns(n as u64 * NS_PER_POINT);
        Ok(TrainOutput {
            update: vec![0.001; DIM],
            loss_sum: n as f64,
            stat_sum: 0.0,
            wsum: n as f64,
            steps: 1,
        })
    }
    fn evaluate(&mut self, _data: &UserData, _sink: Option<&mut ScoreSink>) -> anyhow::Result<Metrics> {
        Ok(Metrics::new())
    }
    fn name(&self) -> &str {
        "spin"
    }
}

fn spin_pool(dataset: Arc<dyn FederatedDataset>) -> WorkerPool {
    let spec = RunSpec { iterations: 100, cohort_size: 16, ..Default::default() };
    WorkerPool::new(
        WORKERS,
        WorkerShared {
            source: Arc::new(GeneratorSource::new(dataset)),
            algorithm: Arc::new(FedAvg::new(spec, Box::new(Sgd))),
            postprocessors: Arc::new(Vec::new()),
            aggregator: Arc::new(SumAggregator),
            factory: Arc::new(|_| Ok(Box::new(SpinModel { central: vec![0.0; DIM] }) as Box<dyn Model>)),
            profile: OverheadProfile::default(),
            seed: 0,
            use_hlo_clip: false,
            arena: pfl::tensor::ArenaConfig::default(),
            noise_threads: 0,
            scenario: Default::default(),
        },
    )
    .unwrap()
}

fn median(mut xs: Vec<u64>) -> u64 {
    xs.sort();
    xs[xs.len() / 2]
}

fn main() -> anyhow::Result<()> {
    let dataset: Arc<dyn FederatedDataset> = Arc::new(LogNormalUsers::new(48, 9));
    let cohort: Vec<usize> = (0..dataset.num_users()).collect();
    let weights: Vec<f64> = cohort.iter().map(|&u| dataset.user_len(u) as f64).collect();
    let pool = spin_pool(dataset.clone());
    let ctx = CentralContext::train(0, cohort.len(), LocalParams::default(), 1);
    let central = Arc::new(vec![0.0f32; DIM]);

    let sched = SchedulerKind::GreedyMedianBase;
    let (mut gaps_static, mut gaps_ws, mut rounds_static, mut rounds_ws) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut steals_total = 0u64;
    for _ in 0..5 {
        // --- static (paper App. B.6) --------------------------------
        let plan = StaticDispatcher { scheduler: sched }.plan(&cohort, &weights, WORKERS);
        let t0 = Instant::now();
        let results = pool.run_round(&ctx, central.clone(), plan.sources)?;
        rounds_static.push(t0.elapsed().as_nanos() as u64);
        let busy: Vec<u64> =
            results.iter().map(|r| r.costs.iter().map(|c| c.nanos).sum()).collect();
        gaps_static.push(straggler_gap_nanos(&busy));

        // --- work-stealing (shared pull queue) ----------------------
        let plan = WorkStealingDispatcher { scheduler: sched }.plan(&cohort, &weights, WORKERS);
        let t0 = Instant::now();
        let results = pool.run_round(&ctx, central.clone(), plan.sources)?;
        rounds_ws.push(t0.elapsed().as_nanos() as u64);
        let busy: Vec<u64> =
            results.iter().map(|r| r.costs.iter().map(|c| c.nanos).sum()).collect();
        let pulled: Vec<u64> = results.iter().map(|r| r.counters.users_trained).collect();
        steals_total += steal_count(&pulled);
        gaps_ws.push(straggler_gap_nanos(&busy));
    }
    pool.shutdown()?;

    let (gap_static, gap_ws) = (median(gaps_static), median(gaps_ws));
    println!("straggler gap (median of 5 rounds, 4 workers, lognormal cohort 48):");
    println!("  static       {:>10.3} ms  (round {:.3} ms)", gap_static as f64 / 1e6, median(rounds_static) as f64 / 1e6);
    println!("  work-steal   {:>10.3} ms  (round {:.3} ms, steals {steals_total})", gap_ws as f64 / 1e6, median(rounds_ws) as f64 / 1e6);
    if gap_ws < gap_static {
        println!("  -> work-stealing gap is {:.1}x smaller", gap_static as f64 / gap_ws.max(1) as f64);
    } else {
        println!("  WARNING: work-stealing gap not smaller than static on this run");
    }

    // --- async: no all-worker barrier -------------------------------
    let spec = RunSpec {
        iterations: 4,
        cohort_size: 16,
        val_cohort_size: 0,
        eval_every: 0,
        population: dataset.num_users(),
        dispatch: DispatchSpec::async_mode(2, 0.5),
        ..Default::default()
    };
    let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
    let mut backend = BackendBuilder::new(
        dataset,
        alg,
        Arc::new(|_| Ok(Box::new(SpinModel { central: vec![0.0; DIM] }) as Box<dyn Model>)),
    )
    .params(RunParams {
        num_workers: WORKERS,
        scheduler: sched,
        dispatch: DispatchSpec::async_mode(2, 0.5),
        ..Default::default()
    })
    .build()?;
    let t0 = Instant::now();
    let out = backend.run(vec![0.0; DIM], &mut [])?;
    let async_wall = t0.elapsed().as_nanos() as u64;
    println!(
        "async: {} rounds in {:.3} ms, stale folds {}, dropped {} (no barrier; gap series all zero: {})",
        out.rounds,
        async_wall as f64 / 1e6,
        out.counters.stale_updates,
        out.counters.dropped_updates,
        out.straggler_nanos.iter().all(|&g| g == 0),
    );

    // --- async deterministic replay: reorder buffer enabled ----------
    let replay = |workers: usize| -> anyhow::Result<(Vec<f32>, u64)> {
        let spec = RunSpec {
            iterations: 4,
            cohort_size: 16,
            val_cohort_size: 0,
            eval_every: 0,
            population: 48,
            dispatch: DispatchSpec::async_replay(2, 0.5, 8),
            ..Default::default()
        };
        let ds: Arc<dyn FederatedDataset> = Arc::new(LogNormalUsers::new(48, 9));
        let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
        let mut backend = BackendBuilder::new(
            ds,
            alg,
            Arc::new(|_| Ok(Box::new(SpinModel { central: vec![0.0; DIM] }) as Box<dyn Model>)),
        )
        .params(RunParams {
            num_workers: workers,
            scheduler: sched,
            dispatch: DispatchSpec::async_replay(2, 0.5, 8),
            ..Default::default()
        })
        .build()?;
        let t0 = Instant::now();
        let out = backend.run(vec![0.0; DIM], &mut [])?;
        Ok((out.central, t0.elapsed().as_nanos() as u64))
    };
    let (c1, _) = replay(1)?;
    let (c4, replay_wall) = replay(WORKERS)?;
    let replay_identical = c1 == c4;
    println!(
        "async replay (window 8): {:.3} ms on {WORKERS} workers; bit-identical to 1 worker: {replay_identical}",
        replay_wall as f64 / 1e6,
    );
    assert!(replay_identical, "replay run diverged across worker counts");

    write_bench_json(
        "BENCH_dispatch.json",
        &[
            BenchRecord {
                name: "dispatch/static/straggler_ns".into(),
                ns_per_op: gap_static as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "dispatch/worksteal/straggler_ns".into(),
                ns_per_op: gap_ws as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "dispatch/worksteal/steals".into(),
                ns_per_op: steals_total as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "dispatch/async/rounds".into(),
                ns_per_op: out.rounds as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "dispatch/async/wall_ns".into(),
                ns_per_op: async_wall as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "dispatch/async-replay/wall_ns".into(),
                ns_per_op: replay_wall as f64,
                alloc_bytes_per_op: 0.0,
            },
            BenchRecord {
                name: "dispatch/async-replay/bit_identical".into(),
                ns_per_op: replay_identical as u64 as f64,
                alloc_bytes_per_op: 0.0,
            },
        ],
    )?;
    println!("wrote BENCH_dispatch.json");
    Ok(())
}
