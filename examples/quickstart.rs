//! Quickstart: federated averaging on the CIFAR10 benchmark in ~20 lines
//! of user code.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart -- --rounds 20
//! ```
//!
//! The flow mirrors pfl-research's quickstart: pick a benchmark preset,
//! shrink it to your compute budget, run, read the accuracy.

use pfl::baselines::EngineVariant;
use pfl::experiments::{run_benchmark, EvalMode};
use pfl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rounds = args.get_u64("rounds", 20)?;
    let cohort = args.get_usize("cohort", 5)?;
    let workers = args.get_usize("workers", 2)?;

    // 1. start from the paper's CIFAR10-IID benchmark (Table 8 values)...
    let mut cfg = pfl::config::preset("cifar10-iid")?;
    // 2. ...shrink it to this machine
    cfg.iterations = rounds;
    cfg.cohort_size = cohort;
    cfg.dataset.num_users = 200;
    cfg.num_workers = workers;
    cfg.eval_every = (rounds / 5).max(1);

    // 3. run and read the headline metric
    let summary = run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::Periodic, 0)?;
    println!("\nround  train-loss  central-accuracy");
    for (t, m) in &summary.outcome.history {
        if let Some(acc) = m.get("centraleval/accuracy") {
            println!(
                "{t:>5}  {:>10.4}  {acc:>16.4}",
                m.get("train/loss").unwrap_or(f64::NAN)
            );
        }
    }
    let (name, v) = summary.headline.unwrap_or(("accuracy".into(), f64::NAN));
    println!(
        "\ntrained {rounds} rounds x cohort {cohort} in {:.1}s -> final {name} {v:.4}",
        summary.wall_secs
    );
    Ok(())
}
