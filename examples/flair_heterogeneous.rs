//! The workload the paper's scheduling machinery exists for: FLAIR-style
//! heavy-tailed user sizes (App. B.6 / Fig. 4) trained with adaptive-clip
//! central DP, comparing greedy load balancing against the uniform split.
//!
//! ```sh
//! cargo run --release --example flair_heterogeneous -- --rounds 10
//! ```

use pfl::baselines::EngineVariant;
use pfl::experiments::{run_benchmark, EvalMode};
use pfl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rounds = args.get_u64("rounds", 10)?;
    let cohort = args.get_usize("cohort", 12)?;
    let workers = args.get_usize("workers", 4)?;

    let mut base = pfl::config::preset("flair-dp")?;
    base.iterations = rounds;
    base.cohort_size = cohort;
    base.dataset.num_users = 500;
    base.num_workers = workers;
    base.eval_every = rounds; // one final central eval
    base.privacy.mechanism = "adaptive-gaussian".into(); // Andrew et al. [5]
    base.privacy.noise_cohort = cohort as f64 * 25.0;

    println!("FLAIR-style heterogeneous benchmark: {cohort}-user cohorts on {workers} workers");
    println!("user sizes are heavy-tailed; DP = Gaussian with adaptive clipping\n");

    for sched in ["uniform", "greedy-median"] {
        let mut cfg = base.clone();
        cfg.scheduler = sched.into();
        cfg.name = format!("flair-het-{sched}");
        let s = run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::Final, 0)?;
        let o = &s.outcome;
        let mean_straggler_ms = o.straggler_nanos.iter().sum::<u64>() as f64
            / o.straggler_nanos.len().max(1) as f64
            / 1e6;
        println!("scheduler={sched:<14}");
        println!("  wall-clock            {:.2}s", s.wall_secs);
        println!("  mean straggler gap    {mean_straggler_ms:.1} ms");
        println!(
            "  final mAP             {}",
            s.headline
                .as_ref()
                .map(|(_, v)| format!("{v:.4}"))
                .unwrap_or_else(|| "-".into())
        );
        println!(
            "  adaptive clip bound   {:.4} (started at {:.4})",
            o.final_metric("dp/clip-bound").unwrap_or(f64::NAN),
            base.privacy.clip_bound,
        );
        println!(
            "  mean SNR              {:.2}\n",
            o.final_metric("dp/snr").unwrap_or(f64::NAN),
        );
    }
    println!("expect: greedy-median shows the smaller straggler gap at equal mAP");
    Ok(())
}
