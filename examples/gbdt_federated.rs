//! Federated gradient-boosted decision trees (paper §1's "non-gradient-
//! descent training"): one tree per central iteration, grown from
//! aggregated gradient histograms — no PJRT involved, the Model trait
//! carries a pure-Rust member of the zoo.
//!
//! ```sh
//! cargo run --release --example gbdt_federated -- --trees 12
//! ```

use std::sync::Arc;

use pfl::fl::backend::{BackendBuilder, RunParams};
use pfl::fl::gbdt::{initial_state, FedGbdt, GbdtModel, GbdtParams};
use pfl::fl::Model;
use pfl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let trees = args.get_usize("trees", 12)?;
    let users = args.get_usize("users", 40)?;
    let workers = args.get_usize("workers", 2)?;

    let p = GbdtParams {
        num_features: 8,
        max_depth: 3,
        max_trees: trees,
        learning_rate: 0.3,
        ..Default::default()
    };
    let spec = pfl::fl::algorithm::RunSpec {
        iterations: trees as u64,
        cohort_size: (users / 2).max(2),
        val_cohort_size: 4,
        eval_every: 1,
        population: users,
        ..Default::default()
    };
    let dataset: Arc<dyn pfl::data::FederatedDataset> =
        Arc::new(pfl::data::SynthTabular::new(users, 64, 8, 7));
    let model_p = p.clone();
    let mut backend = BackendBuilder::new(
        dataset,
        Arc::new(FedGbdt::new(spec, p.clone())),
        Arc::new(move |_| Ok(Box::new(GbdtModel::new(model_p.clone())) as Box<dyn Model>)),
    )
    .params(RunParams { num_workers: workers, ..Default::default() })
    .build()?;

    let out = backend.run(initial_state(&p), &mut [])?;
    println!("tree  train-mse  held-out-mse");
    let val = out.series("val/loss");
    for (t, v) in out.series("train/loss") {
        let held = val
            .iter()
            .find(|(vt, _)| *vt == t)
            .map(|(_, x)| format!("{x:.5}"))
            .unwrap_or_else(|| "-".into());
        println!("{t:>4}  {v:>9.5}  {held}");
    }
    let series = out.series("train/loss");
    println!(
        "\nboosted {} trees in {:.2}s; train MSE {:.4} -> {:.4}",
        out.rounds,
        out.wall_secs,
        series[0].1,
        series.last().unwrap().1
    );
    Ok(())
}
