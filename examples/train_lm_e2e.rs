//! End-to-end driver (DESIGN.md deliverable (b)/EXPERIMENTS.md §E2E):
//! train the StackOverflow benchmark transformer (~2.0M parameters,
//! paper App. C.6) with **FedAdam + central DP** for a few hundred
//! rounds on the synthetic user-keyed corpus, proving that all layers
//! compose on a real workload:
//!
//!   L1 Pallas clip kernel → L2 JAX train/eval steps (AOT HLO) →
//!   PJRT runtime → worker replicas → greedy scheduling → Gaussian
//!   mechanism with PLD-calibrated noise → FedAdam central updates.
//!
//! ```sh
//! cargo run --release --example train_lm_e2e -- --rounds 200 --cohort 8
//! ```
//!
//! Logs the loss/perplexity curve and writes `e2e_lm_curve.csv`; the run
//! recorded in EXPERIMENTS.md used the default arguments.

use pfl::baselines::EngineVariant;
use pfl::fl::callbacks::{Callback, CsvReporter};
use pfl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rounds = args.get_u64("rounds", 200)?;
    let cohort = args.get_usize("cohort", 8)?;
    let workers = args.get_usize("workers", 2)?;
    let csv = args.get_str("csv", "e2e_lm_curve.csv").to_string();

    // The paper's StackOverflow-with-DP benchmark (Tables 7 + 9):
    // FedAdam (lr 0.1, warmup, tau 0.1), clip bound 1.0, eps=2, delta=1e-6,
    // noise cohort 5000 -> r = C/5000 noise rescaling (App. C.4).
    let mut cfg = pfl::config::preset("stackoverflow-dp")?;
    cfg.iterations = rounds;
    cfg.cohort_size = cohort;
    cfg.dataset.num_users = 2_000;
    cfg.num_workers = workers;
    cfg.eval_every = (rounds / 20).max(1);
    cfg.central_opt.warmup = (rounds / 10).max(1);
    // keep the paper's noise *per-user scale* honest at the small cohort:
    // noise cohort C~ = 50x the simulated cohort
    cfg.privacy.noise_cohort = (cohort as f64) * 50.0;

    let sigma = pfl::config::build::calibrated_noise_multiplier(&cfg)?;
    eprintln!(
        "== e2e: {} | T={rounds} C={cohort} workers={workers} ==",
        cfg.name
    );
    eprintln!(
        "== DP: eps={} delta={} accountant={} -> noise multiplier sigma={sigma:.4} (r={:.4}) ==",
        cfg.privacy.epsilon,
        cfg.privacy.delta,
        cfg.privacy.accountant,
        cohort as f64 / cfg.privacy.noise_cohort,
    );

    let dataset = pfl::config::build::build_dataset(&cfg.dataset)?;
    let mut backend =
        pfl::config::build::build_backend(&cfg, EngineVariant::PflStyle.profile())?;
    let init = pfl::config::build::init_params(&cfg)?;
    let mut callbacks: Vec<Box<dyn Callback>> = vec![
        Box::new(pfl::config::build::build_eval_callback(&cfg, &dataset)?),
        Box::new(CsvReporter::new(&csv)),
    ];

    let t0 = std::time::Instant::now();
    let out = backend.run(init, &mut callbacks)?;

    println!("\nround  train-loss  central-ppl  snr");
    for (t, m) in &out.history {
        if let Some(ppl) = m.get("centraleval/perplexity") {
            println!(
                "{t:>5}  {:>10.4}  {ppl:>11.3}  {:>6.2}",
                m.get("train/loss").unwrap_or(f64::NAN),
                m.get("dp/snr").unwrap_or(f64::NAN),
            );
        }
    }
    let first_ppl = out
        .history
        .iter()
        .find_map(|(_, m)| m.get("centraleval/perplexity"))
        .unwrap_or(f64::NAN);
    let final_ppl = out.final_metric("centraleval/perplexity").unwrap_or(f64::NAN);
    println!(
        "\n{} rounds in {:.1}s | {} users trained | perplexity {first_ppl:.2} -> {final_ppl:.2} | curve -> {csv}",
        out.rounds,
        t0.elapsed().as_secs_f64(),
        out.counters.users_trained,
    );
    anyhow::ensure!(
        final_ppl < first_ppl,
        "perplexity did not improve under DP training"
    );
    Ok(())
}
