//! Federated Gaussian mixture model via federated EM (paper §1): clients
//! send E-step sufficient statistics, the server M-steps. Composable with
//! the same aggregation/DP pipeline as the NN models.
//!
//! ```sh
//! cargo run --release --example gmm_federated -- --components 3
//! ```

use std::sync::Arc;

use pfl::fl::backend::{BackendBuilder, RunParams};
use pfl::fl::gmm::{initial_state, FedGmm, GmmModel, GmmParams};
use pfl::fl::Model;
use pfl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let components = args.get_usize("components", 3)?;
    let rounds = args.get_u64("rounds", 20)?;
    let users = args.get_usize("users", 40)?;

    let p = GmmParams { components, dim: 2, var_floor: 1e-3 };
    let spec = pfl::fl::algorithm::RunSpec {
        iterations: rounds,
        cohort_size: (users / 2).max(2),
        val_cohort_size: 4,
        eval_every: 2,
        population: users,
        ..Default::default()
    };
    // point clouds drawn from `components` true clusters
    let dataset: Arc<dyn pfl::data::FederatedDataset> =
        Arc::new(pfl::data::SynthGmmPoints::new(users, 50, 2, components, 13));
    let mut backend = BackendBuilder::new(
        dataset,
        Arc::new(FedGmm::new(spec, p)),
        Arc::new(move |w| Ok(Box::new(GmmModel::new(p, w as u64)) as Box<dyn Model>)),
    )
    .params(RunParams { num_workers: 2, ..Default::default() })
    .build()?;

    let out = backend.run(initial_state(&p, 5), &mut [])?;
    println!("round  train-NLL/point");
    for (t, v) in out.series("train/nll") {
        println!("{t:>5}  {v:.5}");
    }
    let mixture = &out.central;
    println!("\nlearned mixture ({} components):", components);
    for k in 0..components {
        let w = mixture[k];
        let mean = &mixture[components + k * 2..components + k * 2 + 2];
        let var = &mixture[components * 3 + k * 2..components * 3 + k * 2 + 2];
        println!(
            "  pi={w:.3}  mean=({:+.2}, {:+.2})  var=({:.2}, {:.2})",
            mean[0], mean[1], var[0], var[1]
        );
    }
    let series = out.series("train/nll");
    anyhow::ensure!(series.last().unwrap().1 < series[0].1, "EM did not improve NLL");
    Ok(())
}
