//! LLM fine-tuning benchmark (paper App. C.8): frozen-base transformer
//! with LoRA r=8 adapters, trained federatedly with the **banded
//! matrix-factorization mechanism** (DP-FTRL) — only the 9k-parameter
//! adapter vector is ever trained, aggregated, clipped or noised.
//!
//! With `--topk k` each user additionally top-k sparsifies its adapter
//! delta before the DP clip; the surviving coordinates travel as sparse
//! statistics to aggregation (communication research on top of DP —
//! watch `sys/user-update-elems` shrink; the reduced aggregate itself
//! stays dense in the arena by design).
//!
//! ```sh
//! cargo run --release --example llm_lora_dp -- --rounds 40 --flavor aya
//! cargo run --release --example llm_lora_dp -- --rounds 40 --topk 1024
//! ```

use pfl::baselines::EngineVariant;
use pfl::experiments::{run_benchmark, EvalMode};
use pfl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rounds = args.get_u64("rounds", 40)?;
    let cohort = args.get_usize("cohort", 8)?;
    let flavor = args.get_str("flavor", "aya").to_string();

    let mut cfg = pfl::config::preset(&format!("llm-{flavor}-dp"))?;
    cfg.iterations = rounds;
    cfg.cohort_size = cohort;
    cfg.dataset.num_users = 400;
    cfg.num_workers = 2;
    cfg.eval_every = (rounds / 8).max(1);
    cfg.privacy.mechanism = "banded-mf".into();
    cfg.privacy.noise_cohort = cohort as f64 * 25.0;
    cfg.privacy.sparse_top_k = args.get_usize("topk", 0)?;

    let sigma = pfl::config::build::calibrated_noise_multiplier(&cfg)?;
    println!(
        "LLM ({flavor}) LoRA-r8 + banded-MF: T={rounds} C={cohort} sigma={sigma:.4} min-sep=48{}",
        if cfg.privacy.sparse_top_k > 0 {
            format!(" topk={} (sparse updates)", cfg.privacy.sparse_top_k)
        } else {
            String::new()
        }
    );

    let s = run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::Periodic, 0)?;
    println!("\nround  train-loss  perplexity");
    for (t, m) in &s.outcome.history {
        if let Some(ppl) = m.get("centraleval/perplexity") {
            println!("{t:>5}  {:>10.4}  {ppl:>10.3}", m.get("train/loss").unwrap_or(f64::NAN));
        }
    }
    println!(
        "\nadapter params only: {} floats per update; final perplexity {}",
        9216,
        s.headline
            .as_ref()
            .map(|(_, v)| format!("{v:.3}"))
            .unwrap_or_else(|| "-".into())
    );
    Ok(())
}
